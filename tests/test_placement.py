"""PR 6: the global placement engine and the waterfill extraction.

* **parity oracle** — the PRE-extraction ``ResourceArbiter.arbitrate``
  water-filling, replayed verbatim against the refactored arbiter on
  seeded multi-tenant scenarios: allocations must be bit-identical
  (the tentpole's strict-refactor guarantee);
* **solver** — fresh global K-replica solves over node headroom;
* **rebalancer** — priced migrations, the no-flapping guarantee
  (steady load ⇒ zero migrations), skew recovery, determinism of
  ``simulate_cluster(rebalance_at=, scale_at=)``;
* **cross-node preemption and autoscaling**;
* **router satellites** — bounded decision log, weight hints.
"""
import math

import numpy as np
import pytest

from repro.cluster import (LEAST_LOADED, STANDBY, UP, ClusterNode,
                           ClusterRouter, FIRST_FIT, REPLICATE,
                           migration_cost, plan_preemptions, plan_rebalance,
                           plan_scaling, solve_placement, simulate_cluster)
from repro.cluster import placement as pl
from repro.core.types import ElasticSpace
from repro.runtime import (CalibrationStore, GlobalConstraints,
                           ResourceArbiter, model_lut)
from repro.runtime import hwmodel as hm
from repro.runtime import waterfill as wf
from repro.runtime.arbiter import _BACKLOG_MIN, _MAX_FILL_PASSES, Allocation
from repro.traffic import DEGRADE, SHED, SLOClass, poisson

TERMS = hm.RooflineTerms(t_compute=0.02, t_memory=0.008, t_collective=0.004)
SPACE = ElasticSpace(width_mults=(0.5, 0.75, 1.0), ffn_mults=(0.5, 1.0),
                     depth_mults=(0.5, 1.0))


def make_lut(scale=1.0, full_chips=256):
    terms = hm.RooflineTerms(TERMS.t_compute * scale, TERMS.t_memory * scale,
                             TERMS.t_collective * scale)
    return model_lut(SPACE.enumerate(), full_terms=terms,
                     full_chips=full_chips)


def make_nodes(capacities, states=None):
    nodes = [ClusterNode(name=f"n{i}",
                         g_fn=lambda t, c=cap: GlobalConstraints(
                             total_chips=c))
             for i, cap in enumerate(capacities)]
    for n, st in zip(nodes, states or []):
        n.state = st
    return nodes


# --- the parity oracle: PRE-extraction arbitrate, verbatim -------------------

def reference_arbitrate(arb, g):
    """The inline water-filling exactly as ``ResourceArbiter.arbitrate``
    ran it before the PR-6 extraction (PR-5 tree, commit ad12075) —
    same arithmetic, iteration order, comparison keys and epsilons."""

    def min_share_point(w, chips_cap, power_cap, throttle):
        scale = arb._power_scale(w.name)
        pts = arb._lut_for(w).feasible(
            max_latency_ms=w.target_latency_ms, chips_available=chips_cap,
            power_budget_w=(None if math.isinf(power_cap)
                            else power_cap / scale),
            min_accuracy=w.min_accuracy, max_freq=throttle)
        if not pts:
            return None
        return min(pts, key=lambda p: (p.hw_state.chips,
                                       hm.slice_power_w(p.hw_state),
                                       -p.accuracy))

    def best_effort_point(w, chips_cap, power_cap, throttle):
        scale = arb._power_scale(w.name)
        cands = [p for p in arb._lut_for(w).points
                 if p.hw_state.chips <= chips_cap
                 and hm.slice_power_w(p.hw_state) * scale <= power_cap]
        cands = arb._throttled(cands, throttle) or cands
        if not cands:
            return None
        return min(cands, key=lambda p: p.latency_ms)

    order = [w for w in arb._priority_order() if w.active]
    chips_left = g.total_chips
    power_left = (g.power_budget_w if g.power_budget_w is not None
                  else math.inf)
    allocs = {}
    for w in order:
        point = min_share_point(w, chips_left, power_left,
                                g.temperature_throttle)
        feasible = point is not None
        if point is None:
            point = best_effort_point(w, chips_left, power_left,
                                      g.temperature_throttle)
        chips = point.hw_state.chips if point else 0
        power = hm.slice_power_w(point.hw_state) if point else 0.0
        priced = power * arb._power_scale(w.name)
        chips_left -= chips
        power_left -= priced
        allocs[w.name] = Allocation(workload=w.name, point=point,
                                    chips=chips, power_w=power,
                                    feasible=feasible,
                                    priced_power_w=priced)
    fill_order = sorted(order, key=lambda w: (-arb._backlog(w), -w.priority))
    for _ in range(_MAX_FILL_PASSES):
        changed = False
        for w in fill_order:
            cur = allocs[w.name]
            scale = arb._power_scale(w.name)
            cap_chips = cur.chips + chips_left
            cap_power = cur.priced_power_w + power_left
            pts = arb._lut_for(w).feasible(
                max_latency_ms=w.target_latency_ms,
                chips_available=cap_chips,
                power_budget_w=(None if math.isinf(cap_power)
                                else cap_power / scale),
                min_accuracy=w.min_accuracy,
                max_freq=g.temperature_throttle)
            if not pts:
                continue
            if arb._backlog(w) >= _BACKLOG_MIN:
                best = min(pts, key=lambda p: (p.latency_ms, -p.accuracy))
                upgraded = (not cur.feasible or cur.point is None
                            or best.latency_ms
                            < cur.point.latency_ms - 1e-12)
            else:
                best = max(pts, key=lambda p: (p.accuracy, -p.energy_mj))
                upgraded = (not cur.feasible or cur.point is None
                            or best.accuracy > cur.point.accuracy + 1e-12)
            if not upgraded:
                continue
            priced = hm.slice_power_w(best.hw_state) * scale
            chips_left = cap_chips - best.hw_state.chips
            power_left = cap_power - priced
            allocs[w.name] = Allocation(
                workload=w.name, point=best, chips=best.hw_state.chips,
                power_w=hm.slice_power_w(best.hw_state),
                feasible=True, priced_power_w=priced)
            changed = True
        if not changed:
            break
    for w in arb._workloads.values():
        if w.name not in allocs:
            allocs[w.name] = Allocation(workload=w.name, point=None,
                                        chips=0, power_w=0.0,
                                        feasible=False)
    for a in allocs.values():
        a.share = a.chips / g.total_chips if g.total_chips else 0.0
    return allocs


def assert_allocs_identical(want, got):
    assert set(want) == set(got)
    for name, a in want.items():
        b = got[name]
        assert a.point is b.point, name        # the SAME LUT object
        assert a.chips == b.chips, name
        assert a.power_w == b.power_w, name    # bitwise, no tolerance
        assert a.priced_power_w == b.priced_power_w, name
        assert a.feasible == b.feasible, name
        assert a.share == b.share, name


def _random_arbiter(rng, calibration=None):
    arb = ResourceArbiter(calibration=calibration)
    n = int(rng.integers(2, 6))
    for i in range(n):
        lut = make_lut(scale=float(rng.choice([0.5, 1.0, 2.0])))
        arb.register(f"t{i}", lut,
                     target_latency_ms=float(rng.choice(
                         [8.0, 15.0, 40.0, 120.0])),
                     priority=int(rng.integers(0, 4)),
                     min_accuracy=(0.72 if rng.random() < 0.3 else None))
        arb.set_active(
            f"t{i}", rng.random() > 0.15,
            queue_depth=int(rng.integers(0, 12)),
            arrival_rate_rps=float(rng.choice([0.0, 5.0, 40.0])))
    return arb


def test_arbitrate_parity_seeded_scenarios():
    """Property-style strict-refactor check: on 24 seeded multi-tenant
    scenarios the solver-backed arbitrate equals the pre-extraction
    algorithm bit-for-bit."""
    rng = np.random.default_rng(1234)
    for _ in range(24):
        arb = _random_arbiter(rng)
        g = GlobalConstraints(
            total_chips=int(rng.choice([64, 128, 256, 384])),
            power_budget_w=(None if rng.random() < 0.4
                            else float(rng.choice([20e3, 60e3, 150e3]))),
            temperature_throttle=float(rng.choice([1.0, 0.7, 0.55])))
        want = reference_arbitrate(arb, g)
        got = arb.arbitrate(g)
        assert_allocs_identical(want, got)


def test_arbitrate_parity_with_calibration():
    """Parity must survive measured pricing: calibrated LUT latencies
    and per-tenant duty-cycle power scales feed both paths."""
    rng = np.random.default_rng(7)
    for _ in range(6):
        store = CalibrationStore()
        arb = _random_arbiter(rng, calibration=store)
        for name in arb.tenants():
            w = arb._workloads[name]
            pt = w.lut.points[int(rng.integers(0, len(w.lut.points)))]
            for _ in range(4):
                store.note_latency(pt.subnet, 8,
                                   pt.latency_ms * float(rng.uniform(
                                       0.6, 1.6)), max_batch=8)
            store.note_power(name, float(rng.uniform(1e3, 30e3)), 40e3)
        g = GlobalConstraints(total_chips=256, power_budget_w=80e3)
        want = reference_arbitrate(arb, g)
        got = arb.arbitrate(g)
        assert_allocs_identical(want, got)


def test_waterfill_solver_is_pure():
    """Equal inputs, equal grants — repeated calls share no state."""
    lut = make_lut()

    def demand(name, priority, backlog):
        def feasible(chips_cap, power_cap):
            pts = lut.feasible(max_latency_ms=40.0,
                               chips_available=chips_cap,
                               power_budget_w=(None if math.isinf(power_cap)
                                               else power_cap))
            return [wf.PricedPoint(units=p.hw_state.chips,
                                   cost=hm.slice_power_w(p.hw_state),
                                   base_cost=hm.slice_power_w(p.hw_state),
                                   latency_ms=p.latency_ms,
                                   accuracy=p.accuracy,
                                   energy_mj=p.energy_mj, payload=p)
                    for p in pts]
        return wf.Demand(name=name, feasible=feasible, candidates=feasible,
                         priority=priority, backlog=backlog)

    demands = [demand("a", 2, 0.0), demand("b", 1, 6.0)]
    g1 = wf.waterfill(demands, 256, 100e3)
    g2 = wf.waterfill(demands, 256, 100e3)
    assert set(g1) == {"a", "b"}
    for n in g1:
        assert g1[n].point == g2[n].point
        assert g1[n].feasible == g2[n].feasible
    # the backlogged demand trades up from its minimal share toward the
    # fastest point the leftover capacity allows
    min_share = wf.min_share_point(demands[1], 256, math.inf)
    assert g1["b"].point.latency_ms < min_share.latency_ms
    cap = 256 - g1["a"].units
    fast = min(demands[1].feasible(cap, math.inf),
               key=lambda p: (p.latency_ms, -p.accuracy))
    assert g1["b"].point.latency_ms <= fast.latency_ms + 1e-9


# --- the fresh global solve --------------------------------------------------

def test_solve_placement_replicates_when_everything_fits():
    specs = [pl.ClassSpec("a", make_lut(), 40.0, priority=2),
             pl.ClassSpec("b", make_lut(), 120.0, priority=1)]
    plan = solve_placement(specs, make_nodes([256, 256]))
    assert sorted(plan.placements["a"]) == ["n0", "n1"]
    assert sorted(plan.placements["b"]) == ["n0", "n1"]


def test_solve_placement_respects_replica_cap_and_headroom():
    specs = [pl.ClassSpec("a", make_lut(), 40.0, priority=2)]
    plan = solve_placement(specs, make_nodes([256, 256, 256]), replicas=2)
    assert len(plan.placements["a"]) == 2
    # a tight class only fits where capacity allows
    tight = [pl.ClassSpec("t", make_lut(), 10.0, priority=2)]
    plan = solve_placement(tight, make_nodes([64, 256]))
    assert plan.placements["t"] == ["n1"]


def test_solve_placement_backlogged_class_fills_first():
    """Surplus replicas go to the deepest-backlog class first — the
    fill order of the one shared objective."""
    lut = make_lut()
    # equal priority so neither treats the other's share as preemptable
    specs = [pl.ClassSpec("calm", lut, 10.0, priority=2, backlog=0.0),
             pl.ClassSpec("hot", lut, 10.0, priority=2, backlog=50.0)]
    # each 256-chip node hosts exactly one 10ms minimal share (192 chips)
    plan = solve_placement(specs, make_nodes([256, 256, 256]))
    # min-share pass: one replica each; the single leftover node goes
    # to the BACKLOGGED class (backlog-first fill order)
    assert len(plan.placements["hot"]) == 2
    assert len(plan.placements["calm"]) == 1


def test_solve_placement_skips_standby_nodes():
    specs = [pl.ClassSpec("a", make_lut(), 40.0)]
    nodes = make_nodes([256, 256], states=[UP, STANDBY])
    plan = solve_placement(specs, nodes)
    assert plan.placements["a"] == ["n0"]


def test_solve_placement_fallback_places_everywhere():
    specs = [pl.ClassSpec("never", make_lut(), 0.001,
                          fallback_target_ms=500.0)]
    plan = solve_placement(specs, make_nodes([64, 64]))
    assert sorted(plan.placements["never"]) == ["n0", "n1"]
    assert plan.best_effort == ["never"]


# --- priced rebalancing ------------------------------------------------------

def test_migration_cost_is_positive_and_calibration_aware():
    spec = pl.ClassSpec("a", make_lut(), 40.0)
    cost = migration_cost(spec)
    assert cost.seconds > pl.DEFAULT_TRANSFER_S
    assert cost.joules > 0
    store = CalibrationStore()
    pt = min(spec.lut.points, key=lambda p: (p.latency_ms, -p.accuracy))
    for _ in range(8):
        store.note_latency(pt.subnet, 8, pt.latency_ms * 3.0, max_batch=8)
    slow = migration_cost(spec, calibration=store)
    assert slow.seconds > cost.seconds     # measured-slow warmup costs more


def test_plan_rebalance_steady_state_is_empty():
    """Current placements == fresh solve ⇒ no moves, nothing rejected."""
    specs = [pl.ClassSpec("a", make_lut(), 40.0, priority=2, backlog=3.0),
             pl.ClassSpec("b", make_lut(), 120.0, priority=1, backlog=2.0)]
    nodes = make_nodes([256, 256])
    current = {"a": ["n0", "n1"], "b": ["n0", "n1"]}
    plan = plan_rebalance(specs, nodes, current)
    assert plan.moves == [] and plan.rejected == []


def test_plan_rebalance_prices_out_unamortized_adds():
    """A backlog-free class never pays a migration; a deeply backlogged
    one does — hysteresis is the dividing line."""
    nodes = make_nodes([256, 256])
    calm = [pl.ClassSpec("a", make_lut(), 40.0, backlog=0.0)]
    plan = plan_rebalance(calm, nodes, {"a": ["n0"]})
    assert plan.moves == []
    assert [m.kind for m in plan.rejected] == ["add"]
    hot = [pl.ClassSpec("a", make_lut(), 40.0, backlog=2000.0)]
    plan = plan_rebalance(hot, nodes, {"a": ["n0"]}, horizon_s=30.0)
    assert [m.kind for m in plan.moves] == ["add"]
    mv = plan.moves[0]
    assert mv.dst == "n1" and mv.benefit_s > 2.0 * mv.cost_s > 0


def test_plan_rebalance_never_orphans_a_class():
    """Unpaired removes stop at the last replica."""
    specs = [pl.ClassSpec("t", make_lut(), 10.0)]
    # fresh solve fits "t" only on n1; current holds it only on n0 (a
    # 64-chip node a capacity change made infeasible)
    nodes = make_nodes([64, 256])
    plan = plan_rebalance(specs, nodes, {"t": ["n0"]}, horizon_s=30.0)
    kinds = sorted(m.kind for m in plan.moves + plan.rejected)
    assert "move" in kinds or "add" in kinds
    final = set(["n0"])
    for m in plan.moves:
        if m.dst:
            final.add(m.dst)
        if m.src:
            final.discard(m.src)
    assert final                      # never empty


# --- cross-node preemption ---------------------------------------------------

def test_plan_preemptions_evicts_lowest_priority_with_other_home():
    lut = make_lut()
    specs = [pl.ClassSpec("hi", lut, 40.0, priority=3, backlog=20.0),
             pl.ClassSpec("mid", lut, 40.0, priority=2),
             pl.ClassSpec("lo", lut, 40.0, priority=1)]
    nodes = make_nodes([256, 256])
    placements = {"hi": ["n0"], "mid": ["n0", "n1"], "lo": ["n0", "n1"]}
    evs = plan_preemptions(specs, nodes, placements)
    assert evs and evs[0].victim == "lo" and evs[0].node == "n0"
    assert evs[0].for_cls == "hi"


def test_plan_preemptions_never_evicts_a_last_replica():
    lut = make_lut()
    specs = [pl.ClassSpec("hi", lut, 40.0, priority=3, backlog=20.0),
             pl.ClassSpec("lo", lut, 40.0, priority=1)]
    nodes = make_nodes([256])
    placements = {"hi": ["n0"], "lo": ["n0"]}   # lo has nowhere else
    assert plan_preemptions(specs, nodes, placements) == []


def test_plan_preemptions_quiet_class_preempts_nothing():
    lut = make_lut()
    specs = [pl.ClassSpec("hi", lut, 40.0, priority=3, backlog=0.0),
             pl.ClassSpec("lo", lut, 40.0, priority=1)]
    nodes = make_nodes([256, 256])
    placements = {"hi": ["n0"], "lo": ["n0", "n1"]}
    assert plan_preemptions(specs, nodes, placements) == []


# --- autoscaling -------------------------------------------------------------

def test_plan_scaling_spins_up_standby_on_backlog():
    nodes = make_nodes([256, 256], states=[UP, STANDBY])
    plan = plan_scaling(nodes, backlog_per_chip=5.0)
    assert plan.spin_up == ["n1"] and plan.spin_down == []
    # no standby pool: nothing to wake
    assert plan_scaling(make_nodes([256]),
                        backlog_per_chip=5.0).spin_up == []


def test_plan_scaling_spins_down_idle_under_high_price():
    nodes = make_nodes([256, 64])
    plan = plan_scaling(nodes, backlog_per_chip=0.0, energy_price=2.0)
    assert plan.spin_down == ["n1"]          # the smallest UP node parks
    # cheap energy, or the min_nodes floor, keeps everything up
    assert plan_scaling(nodes, backlog_per_chip=0.0,
                        energy_price=0.1).spin_down == []
    assert plan_scaling(nodes, backlog_per_chip=0.0, energy_price=2.0,
                        min_nodes=2).spin_down == []


# --- simulate_cluster scripting ---------------------------------------------

def _cls(name="api", priority=2, drop_policy=SHED, deadline_ms=200.0):
    return SLOClass(name, deadline_ms=deadline_ms, priority=priority,
                    drop_policy=drop_policy)


def test_sim_no_flapping_under_steady_load():
    """The migration-storm guard: steady balanced load across N
    rebalance periods moves NOTHING."""
    rep = simulate_cluster(
        [_cls()], {"api": make_lut()}, {"api": poisson(300.0, 6.0, seed=3)},
        make_nodes([256, 256]), router=LEAST_LOADED,
        rebalance_at=[1.0, 2.0, 3.0, 4.0, 5.0])
    assert rep.migrations == []
    assert rep.preempted == []
    assert rep.total_goodput > 0


def test_sim_rebalance_recovers_skewed_first_fit():
    """First-fit parks the class on one node; the rebalancer pays a
    priced migration to scale it out and goodput improves."""
    kw = dict(classes=[_cls(drop_policy=DEGRADE)],
              luts={"api": make_lut()},
              streams={"api": poisson(2500.0, 4.0, seed=5)},
              router=LEAST_LOADED, placement_mode=FIRST_FIT)
    static = simulate_cluster(nodes=make_nodes([256, 256, 256]), **kw)
    rebal = simulate_cluster(nodes=make_nodes([256, 256, 256]),
                             rebalance_at=[0.5, 1.5, 2.5, 3.5], **kw)
    assert static.migrations == []
    assert len(rebal.migrations) >= 1
    assert all(mv[3] is not None for mv in rebal.migrations)  # adds/moves
    assert rebal.total_goodput > static.total_goodput


def test_sim_rebalance_and_scale_are_deterministic():
    """Same seeded trace + same scripting ⇒ identical routing decisions
    and identical reports — the placement engine adds no nondeterminism."""
    def run():
        return simulate_cluster(
            [_cls(drop_policy=DEGRADE)], {"api": make_lut()},
            {"api": poisson(2500.0, 4.0, seed=11)},
            make_nodes([256, 256, 256], states=[UP, UP, STANDBY]),
            router=LEAST_LOADED, placement_mode=FIRST_FIT,
            rebalance_at=[0.5, 1.5, 2.5], scale_at=[0.4, 1.4, 2.4],
            energy_price_fn=lambda t: 0.2 if t < 2.0 else 2.0)
    a, b = run(), run()
    assert a.decisions == b.decisions
    assert a.migrations == b.migrations
    assert a.scale_events == b.scale_events
    assert a.summary() == b.summary()


def test_sim_autoscaler_spins_up_standby_on_sustained_backlog():
    rep = simulate_cluster(
        [_cls(drop_policy=DEGRADE)], {"api": make_lut()},
        {"api": poisson(3000.0, 4.0, seed=13)},
        make_nodes([256, 256], states=[UP, STANDBY]),
        router=LEAST_LOADED, scale_at=[1.0, 2.0, 3.0])
    ups = [e for e in rep.scale_events if e[1] == "up"]
    assert ups and ups[0][2] == "n1"
    # the woken node really serves: its replica appears in the routing log
    assert any(d[2] == "n1" for d in rep.decisions)


def test_sim_autoscaler_spins_down_idle_node_under_high_price():
    """A trickle the big node absorbs + an expensive grid at the late
    scale instant parks the small idle node back to STANDBY."""
    times = [i * 0.25 for i in range(40)]          # 10s trickle, 4 rps
    rep = simulate_cluster(
        [_cls()], {"api": make_lut()}, {"api": times},
        make_nodes([256, 64]), router=LEAST_LOADED,
        scale_at=[8.0], energy_price_fn=lambda t: 2.0)
    downs = [e for e in rep.scale_events if e[1] == "down"]
    assert len(downs) == 1 and downs[0][2] == "n1"
    assert 8.0 <= downs[0][0] <= 8.5     # the epoch that services t=8.0
    assert rep.nodes["n1"]["state"] == STANDBY


def test_sim_cross_node_preemption_evicts_colocated_replica():
    """A backlogged high-priority class evicts the low-priority replica
    sharing its node; the victim keeps serving from its other home."""
    lut = make_lut()
    rep = simulate_cluster(
        [_cls("hot", priority=3, drop_policy=DEGRADE),
         _cls("bulk", priority=0, drop_policy=DEGRADE)],
        {"hot": lut, "bulk": lut},
        {"hot": poisson(2500.0, 3.0, seed=17),
         "bulk": poisson(50.0, 3.0, seed=18)},
        make_nodes([256, 256]), router=LEAST_LOADED,
        rebalance_at=[0.5])
    assert any(p[1] == "bulk" and p[3] == "hot" for p in rep.preempted)
    assert rep.classes["bulk"].completed > 0      # survived elsewhere


# --- router satellites -------------------------------------------------------

def test_router_decision_log_is_bounded():
    nodes = make_nodes([64, 64])
    r = ClusterRouter(LEAST_LOADED, decision_log_cap=8)
    for i in range(20):
        r.pick("a", nodes, t=float(i))
    assert len(r.decisions) == 8
    assert r.decisions_dropped == 12
    # the NEWEST picks are kept (like the engine's switch_log)
    assert [d[0] for d in r.decisions] == [float(i) for i in range(12, 20)]
    # aggregate counts still see everything
    assert sum(r.routed_counts()["a"].values()) == 20


def test_router_weight_zero_takes_replica_out_of_rotation():
    nodes = make_nodes([64, 64])
    r = ClusterRouter(LEAST_LOADED)
    r.set_weight("a", "n0", 0.0)
    assert all(r.pick("a", nodes).name == "n1" for _ in range(4))
    r.set_weight("a", "n0", None)               # cleared: back in rotation
    assert r.pick("a", nodes, load_fn=lambda n: 0.0).name == "n0"
    # weights scale the compared load: a weight-4 node looks 4x lighter
    r.set_weight("a", "n1", 4.0)
    assert r.pick("a", nodes,
                  load_fn=lambda n: 1.0 if n.name == "n1" else 0.5
                  ).name == "n1"


# --- the perf-gate smoke test ------------------------------------------------

def test_run_py_compare_gates_placement_headline(tmp_path):
    """End-to-end ``run.py --suite placement --smoke --json --compare``:
    the placement suite runs (its own acceptance asserts fire), the gate
    passes against an honest previous file and exits non-zero against a
    fabricated better past."""
    import json
    import pathlib
    import subprocess
    import sys
    root = pathlib.Path(__file__).resolve().parents[1]
    out = tmp_path / "now.json"

    def gate(prev_path):
        return subprocess.run(
            [sys.executable, "benchmarks/run.py", "--suite", "placement",
             "--smoke", "--json", str(out), "--compare", str(prev_path)],
            cwd=root, env={"PYTHONPATH": "src:.", "PATH": "/usr/bin:/bin"},
            capture_output=True, text=True)

    # seed the previous file from a first smoke run (no --compare)
    first = subprocess.run(
        [sys.executable, "benchmarks/run.py", "--suite", "placement",
         "--smoke", "--json", str(out)],
        cwd=root, env={"PYTHONPATH": "src:.", "PATH": "/usr/bin:/bin"},
        capture_output=True, text=True)
    assert first.returncode == 0, first.stderr
    prev = tmp_path / "prev.json"
    prev.write_text(out.read_text())

    ok = gate(prev)
    assert ok.returncode == 0, ok.stdout + ok.stderr
    assert "no headline regression" in ok.stdout

    # a past that claims a far higher goodput ratio must trip the gate
    doc = json.loads(prev.read_text())
    for rows in doc["suites"].values():
        for row in rows:
            if row["name"] == "placement/rebalance_goodput_ratio":
                row["value"] = row["value"] * 100.0
    prev.write_text(json.dumps(doc))
    bad = gate(prev)
    assert bad.returncode == 2
    assert "REGRESSION placement/rebalance_goodput_ratio" in bad.stdout
