"""Paper mechanism: sub-network switching overhead.

Dynamic-OFA's point is that switching among pre-selected sub-networks is
cheap at runtime (weights stay resident).  Measures: cold switch (first
compile), warm switch (executable-cache hit), and the masked-mode
alternative (zero switch cost, one executable, via the elastic kernel
path) for the trade-off table in EXPERIMENTS.md.  A second server warms
the full bucket ladder up front and then serves mixed batch sizes:
steady-state serving must perform ZERO cold compiles and zero cold
switches (asserted).

All rows report milliseconds (an earlier revision multiplied the
already-in-ms switch_log values by 1e3 under ``_ms`` labels).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.elastic import spec_to_dynamic
from repro.core.types import SubnetSpec
from repro.runtime import DynamicServer


def run():
    arch = get_arch("dynamic-ofa-supernet")
    cfg = arch.make_smoke()
    from repro.models.vit import vit_apply, vit_init
    params = vit_init(jax.random.PRNGKey(0), cfg)
    dims = {"d_model": cfg.d_model, "d_ff": cfg.d_ff,
            "n_heads": cfg.n_heads, "n_layers": cfg.n_layers}
    apply_fn = lambda p, x, E: vit_apply(p, x, cfg, E=E)[0]
    server = DynamicServer(apply_fn, params, dims, max_batch=4)
    x = np.zeros((4, cfg.img_res, cfg.img_res, 3), "float32")
    half = SubnetSpec(width_mult=0.5, ffn_mult=0.5, depth_mult=2 / 3)

    server.switch(half)                      # cold: includes jit trace
    cold_ms = server.switch_log[-1]["ms"]
    server.infer(x)                          # executes (excluded from switch)
    server.switch(SubnetSpec())
    server.switch(half)                      # warm: cache hit
    warm_ms = server.switch_log[-1]["ms"]

    # masked-mode single executable: no switch cost at all, lower throughput
    E_dyn = spec_to_dynamic(half, dims)
    masked = jax.jit(lambda p, x, E: vit_apply(p, x, cfg, E=E)[0])
    jax.block_until_ready(masked(params, x, E_dyn))
    t0 = time.perf_counter()
    for _ in range(5):
        jax.block_until_ready(masked(params, x, E_dyn))
    masked_ms = (time.perf_counter() - t0) / 5 * 1e3
    sliced_ms = server.measure(half, x)

    # bucket-ladder warmup: pre-compile every (subnet, bucket) executable,
    # then serve mixed batch sizes across governor switches — the steady
    # state must hit the cache every time (zero cold compiles/switches)
    specs = [SubnetSpec(), half]
    warm_server = DynamicServer(apply_fn, params, dims, max_batch=4,
                                timeout_ms=2.0, warm_specs=specs,
                                example_input=x[0])
    warm_server.start()
    futs = []
    for spec in (specs * 2):                 # switch churn across the ladder
        warm_server.switch(spec)
        for k in (1, 2, 3, 4):               # every bucket gets exercised
            futs += [warm_server.submit(x[0]) for _ in range(k)]
            time.sleep(0.01)
    outs = [f.get(timeout=60) for f in futs]
    warm_server.stop()
    cold_switches = sum(e["cold"] for e in warm_server.switch_log)
    assert all(not o.get("cancelled") for o in outs)
    assert warm_server.cold_compiles == 0, (
        f"{warm_server.cold_compiles} cold compiles after ladder warmup")
    assert cold_switches == 0, f"{cold_switches} cold switches after warmup"

    return [
        ("switching/cold_compile_ms", cold_ms, "first use of a subnet"),
        ("switching/warm_switch_ms", warm_ms,
         "steady-state governor switch (cache hit)"),
        ("switching/sliced_infer_ms", sliced_ms, "per-batch, sliced"),
        ("switching/masked_infer_ms", masked_ms,
         "per-batch, masked single-executable (zero-switch alternative)"),
        ("switching/cold_compiles_after_warmup", warm_server.cold_compiles,
         f"bucket ladder warmed: {len(specs)} subnets x "
         f"{len(warm_server.buckets)} buckets, {warm_server.served} reqs "
         f"served, {cold_switches} cold switches"),
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(str(c) for c in r))
