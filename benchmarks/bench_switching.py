"""Paper mechanism: sub-network switching overhead.

Dynamic-OFA's point is that switching among pre-selected sub-networks is
cheap at runtime (weights stay resident).  Measures: cold switch (first
compile), warm switch (executable-cache hit), and the masked-mode
alternative (zero switch cost, one executable, via the elastic kernel
path) for the trade-off table in EXPERIMENTS.md.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.elastic import spec_to_dynamic
from repro.core.types import SubnetSpec
from repro.runtime import DynamicServer


def run():
    arch = get_arch("dynamic-ofa-supernet")
    cfg = arch.make_smoke()
    from repro.models.vit import vit_apply, vit_init
    params = vit_init(jax.random.PRNGKey(0), cfg)
    dims = {"d_model": cfg.d_model, "d_ff": cfg.d_ff,
            "n_heads": cfg.n_heads, "n_layers": cfg.n_layers}
    server = DynamicServer(lambda p, x, E: vit_apply(p, x, cfg, E=E)[0],
                           params, dims, max_batch=4)
    x = np.zeros((4, cfg.img_res, cfg.img_res, 3), "float32")
    half = SubnetSpec(width_mult=0.5, ffn_mult=0.5, depth_mult=2 / 3)

    server.switch(half)                      # cold: includes jit compile
    cold_ms = server.switch_log[-1]["ms"]
    server.infer(x)                          # executes (excluded from switch)
    server.switch(SubnetSpec())
    server.switch(half)                      # warm: cache hit
    warm_ms = server.switch_log[-1]["ms"]

    # masked-mode single executable: no switch cost at all, lower throughput
    E_dyn = spec_to_dynamic(half, dims)
    masked = jax.jit(lambda p, x, E: vit_apply(p, x, cfg, E=E)[0])
    jax.block_until_ready(masked(params, x, E_dyn))
    t0 = time.perf_counter()
    for _ in range(5):
        jax.block_until_ready(masked(params, x, E_dyn))
    masked_ms = (time.perf_counter() - t0) / 5 * 1e3
    sliced_ms = server.measure(half, x)

    return [
        ("switching/cold_compile_ms", cold_ms * 1e3, "first use of a subnet"),
        ("switching/warm_switch_ms", warm_ms * 1e3,
         "steady-state governor switch (cache hit)"),
        ("switching/sliced_infer_ms", sliced_ms * 1e3, "per-batch, sliced"),
        ("switching/masked_infer_ms", masked_ms * 1e3,
         "per-batch, masked single-executable (zero-switch alternative)"),
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(str(c) for c in r))
