"""Kernel-level benchmark: elastic-width compute scaling.

On this CPU container the Pallas kernels run in interpret mode (timing is
meaningless for TPU), so the wall-clock rows come from the XLA sliced path
— demonstrating that sub-network compute genuinely shrinks — and the
kernel rows report correctness + the analytic MXU-work ratio the elastic
kernel achieves by skipping dead tiles.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import elastic_matmul_op
from repro.kernels.ref import elastic_matmul_ref


def _time(fn, *args, iters=5):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run():
    M, K, N = 512, 1024, 1024
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (M, K))
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N))
    rows = []

    # XLA sliced matmuls: compute scales ~quadratically with width
    for frac in (1.0, 0.75, 0.5, 0.25):
        ka, na = int(K * frac), int(N * frac)
        f = jax.jit(lambda a, b: a @ b)
        us = _time(f, x[:, :ka], w[:ka, :na])
        rows.append((f"kernel/xla_sliced_w{frac:g}", us,
                     f"{ka}x{na} of {K}x{N}"))

    # elastic kernel: correctness + tile-skip work ratio
    for frac in (1.0, 0.5, 0.25):
        ka, na = int(K * frac), int(N * frac)
        y = elastic_matmul_op(x, w, ka, na)
        yr = elastic_matmul_ref(x, w, ka, na)
        err = float(jnp.max(jnp.abs(y - yr)))
        live_tiles = -(-ka // 128) * -(-na // 128)
        total_tiles = (K // 128) * (N // 128)
        rows.append((f"kernel/elastic_w{frac:g}_tile_work",
                     100.0 * live_tiles / total_tiles,
                     f"% of MXU tiles live; max_err={err:.2e}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(c) for c in r))
