"""Paper result 2: runtime governor energy/violations vs Linux governors.

LUT anchored to the REAL dry-run roofline terms of the paper-representative
serving cell (deit-b x serve_b128 on the 16x16 pod); the paper's claim is
~16.5% energy reduction vs performance/schedutil at similar latency.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import get_arch
from repro.core.types import SubnetSpec
from repro.runtime import (Constraints, JointGovernor, PerformanceGovernor,
                           SchedutilGovernor, StaticPrunedGovernor,
                           model_lut, paper_trace, run_governor)
from repro.runtime import hwmodel as hm

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _anchor_terms():
    path = os.path.join(ROOT, "benchmarks/results/dryrun",
                        "deit-b__serve_b128__pod1__base.json")
    if os.path.exists(path):
        d = json.load(open(path))
        if d.get("status") == "ok":
            return hm.RooflineTerms(d["t_compute"], d["t_memory"],
                                    d["t_collective"]), d["chips"]
    return hm.RooflineTerms(2e-4, 4e-4, 1e-4), 256


def run(steps: int = 400):
    arch = get_arch("deit-b")
    space = arch.make_config().elastic
    terms, chips = _anchor_terms()
    lut = model_lut(space.enumerate(), full_terms=terms, full_chips=chips)
    base_ms = max(terms.t_total * 1e3 * 1.2, 0.05)
    full = SubnetSpec()
    trace = lambda: paper_trace(steps, chips=chips, base_target_ms=base_ms)

    results = {}
    for name, gov in [
        ("joint", JointGovernor(lut)),
        ("performance", PerformanceGovernor(lut, full)),
        ("schedutil", SchedutilGovernor(lut, full)),
        ("static-pruned", StaticPrunedGovernor(
            lut, worst_case=Constraints(target_latency_ms=base_ms * 0.5,
                                        chips_available=chips // 2))),
    ]:
        results[name] = run_governor(gov, trace()).summary()

    rows = []
    for name, s in results.items():
        rows.append((f"governor/{name}/energy_mj", s["energy_mj"],
                     f"viol={s['violation_rate']:.3f} "
                     f"acc={s['mean_accuracy']:.2f} "
                     f"lat={s['mean_latency_ms']:.3f}ms"))
    e_joint = results["joint"]["energy_mj"]
    for base in ("performance", "schedutil"):
        sav = 100 * (1 - e_joint / results[base]["energy_mj"])
        rows.append((f"governor/energy_saving_vs_{base}_pct", sav,
                     "paper claims 16.5% vs Linux governors"))
    dacc = (results["joint"]["mean_accuracy"]
            - results["static-pruned"]["mean_accuracy"])
    rows.append(("governor/accuracy_gain_vs_static_pct", dacc,
                 "paper claims +3.8-5.1% at similar latency"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(c) for c in r))
