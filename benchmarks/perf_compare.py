"""§Perf helper: compare dry-run variants of a cell (hypothesis -> change ->
before -> after), printing the three roofline terms side by side.

  python benchmarks/perf_compare.py kimi-k2-1t-a32b train_4k base a2a
"""
from __future__ import annotations

import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRY = os.path.join(ROOT, "benchmarks", "results", "dryrun")


def load(arch, shape, mesh, variant):
    p = os.path.join(DRY, f"{arch}__{shape}__{mesh}__{variant}.json")
    return json.load(open(p))


def compare(arch, shape, variants, mesh="pod1"):
    recs = [load(arch, shape, mesh, v) for v in variants]
    keys = [("t_compute", 1e3, "ms"), ("t_memory", 1e3, "ms"),
            ("t_collective", 1e3, "ms"), ("t_total", 1e3, "ms"),
            ("flops_per_dev", 1e-12, "TF"), ("bytes_per_dev", 1e-9, "GB"),
            ("coll_bytes_per_dev", 1e-9, "GB"),
            ("hbm_gb_per_dev", 1, "GB"), ("useful_ratio", 1, "x")]
    print(f"{arch} x {shape} ({mesh})")
    hdr = f"{'metric':22s}" + "".join(f"{v:>16s}" for v in variants)
    print(hdr)
    for k, scale, unit in keys:
        row = f"{k:22s}"
        base = None
        for r in recs:
            val = r.get(k, float('nan')) * scale
            base = base if base is not None else val
            delta = "" if r is recs[0] or not base else \
                f" ({(val/base-1)*100:+.0f}%)"
            row += f"{val:10.3f}{unit}{delta:>5s}"[:16].rjust(16)
        print(row)
    for r, v in zip(recs, variants):
        print(f"  [{v}] bottleneck={r['bottleneck']} "
              f"coll={ {k: round(b/1e9,1) for k,b in r['coll_detail'].items()} }")


if __name__ == "__main__":
    arch, shape = sys.argv[1], sys.argv[2]
    compare(arch, shape, sys.argv[3:] or ["base"])
