"""Multi-workload arbitration: water-filling arbiter vs independent
governors on a shared machine.

Three concurrent workloads (an LLM-serve cell, a vision cell, a background
batch job) share one chip pool and power budget through a contention trace
(co-running phases shrink the pool, a thermal window caps frequency).  The
baseline runs one JointGovernor per workload, each believing it owns the
whole machine — when their combined demand oversubscribes the pool the
slice is time-shared and every workload's latency (and energy) stretches by
the oversubscription factor.  The arbiter never oversubscribes: it grants
minimal feasible shares by priority and water-fills the surplus into
accuracy.

    PYTHONPATH=src python benchmarks/bench_arbiter.py
"""
from __future__ import annotations

import dataclasses

from repro.core.types import ElasticSpace
from repro.runtime import (GlobalConstraints, JointGovernor, ResourceArbiter,
                           default_hw_states, model_lut)
from repro.runtime import hwmodel as hm

TOTAL_CHIPS = 256
POWER_BUDGET_W = 0.9 * TOTAL_CHIPS * hm.TDP_W

SPACE = ElasticSpace(width_mults=(0.5, 0.75, 1.0), ffn_mults=(0.5, 1.0),
                     depth_mults=(0.5, 1.0))

# (name, roofline scale vs the reference cell, latency target ms, priority)
WORKLOADS = (
    ("llm-serve", 1.0, 40.0, 2),
    ("vision", 0.4, 20.0, 1),
    ("batch", 1.6, 150.0, 0),
)

_REF_TERMS = hm.RooflineTerms(t_compute=0.02, t_memory=0.008,
                              t_collective=0.004)


def make_luts():
    # concurrent tenants need small slice quanta or water-filling can't
    # pack them — default_hw_states provides the 8-tier ladder down to 1/16
    hw_states = default_hw_states(TOTAL_CHIPS)
    luts = {}
    for name, scale, _, _ in WORKLOADS:
        terms = hm.RooflineTerms(_REF_TERMS.t_compute * scale,
                                 _REF_TERMS.t_memory * scale,
                                 _REF_TERMS.t_collective * scale)
        luts[name] = model_lut(SPACE.enumerate(), full_terms=terms,
                               full_chips=TOTAL_CHIPS, hw_states=hw_states)
    return luts


def global_trace(n_steps: int = 300):
    """Shared machine conditions: co-running phases shrink the pool,
    a thermal window caps the ladder (mirrors monitor.paper_trace)."""
    for i in range(n_steps):
        chips = TOTAL_CHIPS
        if 100 <= i < 160:
            chips = TOTAL_CHIPS // 2
        elif 200 <= i < 240:
            chips = TOTAL_CHIPS // 4
        throttle = 0.7 if 120 <= i < 180 else 1.0
        yield GlobalConstraints(total_chips=chips,
                                power_budget_w=POWER_BUDGET_W
                                * chips / TOTAL_CHIPS,
                                temperature_throttle=throttle)


@dataclasses.dataclass
class Tally:
    met: int = 0
    steps: int = 0
    energy_mj: float = 0.0

    @property
    def meet_rate(self):
        return self.met / self.steps if self.steps else 0.0


def run_arbitrated(luts, trace):
    arb = ResourceArbiter()
    for name, _, target, prio in WORKLOADS:
        arb.register(name, luts[name], target_latency_ms=target,
                     priority=prio)
    tallies = {name: Tally() for name, *_ in WORKLOADS}
    for g in trace:
        allocs = arb.tick(g)
        for name, _, target, _ in WORKLOADS:
            a = allocs[name]
            t = tallies[name]
            t.steps += 1
            if a.point is not None:
                t.met += a.point.latency_ms <= target
                t.energy_mj += a.point.energy_mj
    return tallies


def run_independent(luts, trace):
    """Per-workload governors, each granted the FULL machine; contention is
    settled by time-sharing (latency and energy stretch together)."""
    from repro.runtime import Constraints
    govs = {name: JointGovernor(luts[name]) for name, *_ in WORKLOADS}
    tallies = {name: Tally() for name, *_ in WORKLOADS}
    for g in trace:
        picks = {}
        for name, _, target, _ in WORKLOADS:
            picks[name] = govs[name].select(Constraints(
                target_latency_ms=target, chips_available=g.total_chips,
                power_budget_w=g.power_budget_w,
                temperature_throttle=g.temperature_throttle))
        chip_demand = sum(p.hw_state.chips for p in picks.values())
        power_demand = sum(hm.slice_power_w(p.hw_state)
                           for p in picks.values())
        stretch = max(1.0, chip_demand / g.total_chips,
                      power_demand / g.power_budget_w
                      if g.power_budget_w else 1.0)
        for name, _, target, _ in WORKLOADS:
            p = picks[name]
            t = tallies[name]
            t.steps += 1
            t.met += p.latency_ms * stretch <= target
            t.energy_mj += p.energy_mj * stretch
    return tallies


def run_static_split(luts, trace):
    """Fixed equal partition of the pool — no arbitration, no priority."""
    from repro.runtime import Constraints
    govs = {name: JointGovernor(luts[name]) for name, *_ in WORKLOADS}
    tallies = {name: Tally() for name, *_ in WORKLOADS}
    n = len(WORKLOADS)
    for g in trace:
        for name, _, target, _ in WORKLOADS:
            grant = max(g.total_chips // n, 1)
            p = govs[name].select(Constraints(
                target_latency_ms=target,
                chips_available=grant,
                power_budget_w=(g.power_budget_w / n
                                if g.power_budget_w else None),
                temperature_throttle=g.temperature_throttle))
            # the governor's degraded fallback may exceed the static share;
            # time-share the overdraft like the independent baseline
            stretch = max(1.0, p.hw_state.chips / grant)
            t = tallies[name]
            t.steps += 1
            t.met += p.latency_ms * stretch <= target
            t.energy_mj += p.energy_mj * stretch
    return tallies


def run(steps: int = 300):
    luts = make_luts()
    results = {
        "arbiter": run_arbitrated(luts, global_trace(steps)),
        "independent": run_independent(luts, global_trace(steps)),
        "static-split": run_static_split(luts, global_trace(steps)),
    }

    rows = []
    for policy, tallies in results.items():
        for name, *_ in WORKLOADS:
            rows.append((f"{policy}/{name}/meet_rate",
                         round(tallies[name].meet_rate, 4),
                         f"energy={tallies[name].energy_mj:.0f}mJ"))
    totals = {policy: (sum(t.met for t in tallies.values()),
                       sum(t.energy_mj for t in tallies.values()))
              for policy, tallies in results.items()}
    for policy, (met, energy) in totals.items():
        rows.append((f"{policy}/targets_met_total", met,
                     f"total_energy_mj={round(energy, 1)}"))
    arb_met = totals["arbiter"][0]
    for policy in ("independent", "static-split"):
        assert arb_met >= totals[policy][0], (
            f"arbiter met {arb_met} targets, {policy} met "
            f"{totals[policy][0]}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(c) for c in r))
