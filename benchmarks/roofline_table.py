"""§Roofline table generator: reads the dry-run records and emits the
per-(arch x shape x mesh) roofline analysis for EXPERIMENTS.md."""
from __future__ import annotations

import glob
import json
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRY = os.path.join(ROOT, "benchmarks", "results", "dryrun")


def load(variant="base", mesh=None):
    recs = []
    for f in sorted(glob.glob(os.path.join(DRY, f"*__{variant}.json"))):
        d = json.load(open(f))
        if mesh and d.get("mesh") != mesh:
            continue
        recs.append(d)
    return recs


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.0f}us"


def table(variant="base", mesh="pod1") -> str:
    recs = [r for r in load(variant, mesh) if r["status"] == "ok"]
    hdr = ("| arch | shape | kind | t_comp | t_mem | t_coll | bottleneck | "
           "HBM/dev | fits v5e | useful |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | "
            f"{fmt_s(r['t_compute'])} | {fmt_s(r['t_memory'])} | "
            f"{fmt_s(r['t_collective'])} | **{r['bottleneck']}** | "
            f"{r['hbm_gb_per_dev']:.1f}GB | "
            f"{'yes' if r['fits_v5e'] else 'NO'} | "
            f"{r['useful_ratio']:.2f} |")
    return "\n".join(lines)


def rows(variant="base"):
    out = []
    for r in load(variant):
        if r["status"] != "ok":
            out.append((f"dryrun/{r['arch']}/{r['shape']}/{r['mesh']}", -1,
                        f"FAILED {r.get('error','')[:60]}"))
        else:
            out.append((
                f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
                r["t_total"] * 1e6,
                f"bottleneck={r['bottleneck']} useful={r['useful_ratio']:.2f}"
                f" hbm={r['hbm_gb_per_dev']:.1f}GB"))
    return out


if __name__ == "__main__":
    print(table())
