"""SLO watchtower day: alert-driven actuation vs reactive baseline.

One deterministic virtual-time "throttle day": a 4-node cluster runs
with half its fleet in the standby pool, and at t=2s a deep thermal
DVFS ladder throttles BOTH up nodes for most of the horizon.  The
throttle makes interactive completions LATE (and sheds predicted
misses) without failing anything — exactly the fault class PR 8's
failure-pressure EWMA is blind to.  The same seeded day is replayed
twice with the same :class:`repro.obs.Watchtower` configuration:

* **reactive** — the watchtower monitors only (``actuate=False``); the
  cluster relies on PR 8's reliability layer and the SCHEDULED
  autoscale instant late in the day;
* **alerted** — the watchtower actuates: fast-burn alert pressure
  boosts the class's demand in every replica's water-fill, a sustained
  fast-burn alert relaxes the arbiter's quality target (degrade without
  suspending admission control), and the rising edge triggers the
  autoscaler NOW — standby capacity comes up within epochs of the
  burn, not at the scheduled instant.

Headlines (compare-gated in run.py, floors asserted here):

* ``slo/attribution_accuracy`` — fraction of fired alerts whose
  top-ranked cause names the injected fault (``chaos:thermal``);
  floor 0.8 per the PR acceptance;
* ``slo/alerted_time_in_slo_ratio`` — alerted / reactive time-in-SLO
  for the interactive class (fraction of evaluate ticks with no active
  fast-burn alert); must be >= 1.0: alerts must pay for themselves.

    PYTHONPATH=src python benchmarks/bench_slo.py [--smoke]
"""
from __future__ import annotations

from repro.chaos import (THERMAL, BrownoutPolicy, Injection, Reliability,
                         RetryBudget, RetryPolicy, Scenario)
from repro.cluster import P2C, ClusterNode, simulate_cluster
from repro.cluster.node import STANDBY
from repro.core.types import ElasticSpace
from repro.obs import Tracer, Watchtower
from repro.runtime import GlobalConstraints, model_lut
from repro.runtime import hwmodel as hm
from repro.traffic import DEGRADE, SHED, SLOClass, poisson

ATTRIBUTION_FLOOR = 0.8   # alerts naming the injected cause (acceptance)
TIS_RATIO_FLOOR = 1.0     # alerted / reactive time-in-SLO (acceptance)
FULL_CHIPS = 256
# deep DVFS ladder: the stock one bottoms at 0.5x, which this fleet
# absorbs without a single late request — no burn, no test
LADDER = (0.2, 0.12, 0.08)

SPACE = ElasticSpace(width_mults=(0.5, 0.75, 1.0), ffn_mults=(0.5, 1.0),
                     depth_mults=(0.5, 1.0))
_REF_TERMS = hm.RooflineTerms(t_compute=0.02, t_memory=0.008,
                              t_collective=0.004)


def make_lut():
    return model_lut(SPACE.enumerate(), full_terms=_REF_TERMS,
                     full_chips=FULL_CHIPS)


def make_nodes():
    # n0/n1 serve; n2/n3 are the standby pool the autoscaler can tap
    return [ClusterNode(name=f"n{i}",
                        g_fn=lambda t: GlobalConstraints(total_chips=16),
                        state=(STANDBY if i >= 2 else "up"))
            for i in range(4)]


def make_classes():
    return [SLOClass("interactive", deadline_ms=600.0, priority=3,
                     drop_policy=SHED, degrade_factor=1.5),
            SLOClass("batch", deadline_ms=2500.0, priority=1,
                     drop_policy=DEGRADE)]


def throttle_day(horizon_s: float) -> Scenario:
    """Both up nodes walk a deep thermal ladder for most of the day."""
    dur = max(1.0, horizon_s - 3.0)
    return Scenario(name="throttle-day", seed=0, injections=(
        Injection(t=2.0, kind=THERMAL, node="n0", duration_s=dur,
                  ladder=LADDER),
        Injection(t=2.0, kind=THERMAL, node="n1", duration_s=dur,
                  ladder=LADDER)))


def make_reliability() -> Reliability:
    return Reliability(
        policies={},
        default=RetryPolicy(max_attempts=3, backoff_s=0.1),
        budget=RetryBudget(fraction=2.0, burst=512),
        brownout=BrownoutPolicy())


def run_day(horizon_s: float, actuate: bool):
    tracer = Tracer(clock=lambda: 0.0)
    wt = Watchtower({"interactive": 0.999, "batch": 0.99},
                    time_scale=horizon_s / 86400.0, tracer=tracer,
                    actuate=actuate, rebalance_on_alert=actuate)
    report = simulate_cluster(
        make_classes(), {"interactive": make_lut(), "batch": make_lut()},
        {"interactive": poisson(200.0, horizon_s, seed=7),
         "batch": poisson(100.0, horizon_s, seed=8)},
        make_nodes(), router=P2C, chaos=throttle_day(horizon_s),
        reliability=make_reliability(), tracer=tracer, watchtower=wt,
        scale_at=(0.8 * horizon_s,), min_nodes=2)
    return report, wt


def attribution_accuracy(report) -> float:
    """Fraction of fired alerts whose top cause is the injected fault."""
    if not report.alerts:
        return 0.0
    hits = sum(1 for a in report.alerts
               if a.attribution is not None
               and a.attribution.cause == f"chaos:{THERMAL}")
    return hits / len(report.alerts)


def run(smoke: bool = False):
    horizon_s = 7.0 if smoke else 10.0
    rows = []

    reactive, wt_off = run_day(horizon_s, actuate=False)
    alerted, wt_on = run_day(horizon_s, actuate=True)

    # the day must actually page — a quiet day proves nothing
    assert alerted.alerts and reactive.alerts, (
        "throttle day fired no alerts — scenario no longer burns")

    acc = attribution_accuracy(alerted)
    rows.append(("slo/attribution_accuracy", acc,
                 f"{sum(1 for a in alerted.alerts if a.attribution and a.attribution.cause == 'chaos:thermal')}"
                 f"/{len(alerted.alerts)} alerts named chaos:thermal"))
    assert acc >= ATTRIBUTION_FLOOR, (
        f"attribution accuracy {acc:.2f} < {ATTRIBUTION_FLOOR} "
        f"(acceptance): "
        f"{[(a.t, a.cls, a.attribution.cause if a.attribution else None) for a in alerted.alerts]}")

    tis_off = wt_off.time_in_slo("interactive")
    tis_on = wt_on.time_in_slo("interactive")
    ratio = tis_on / max(tis_off, 1e-9)
    rows.append(("slo/alerted_time_in_slo_ratio", ratio,
                 f"time-in-SLO {tis_on:.3f} alerted vs {tis_off:.3f} "
                 f"reactive, {len(alerted.alerts)} vs "
                 f"{len(reactive.alerts)} alerts"))
    assert ratio >= TIS_RATIO_FLOOR, (
        f"alert-driven actuation ratio {ratio:.3f} < {TIS_RATIO_FLOOR} "
        f"(acceptance): alerts must not make the day worse")

    g_off = reactive.summary()["classes"]["interactive"]
    g_on = alerted.summary()["classes"]["interactive"]
    rows.append(("slo/alerted_goodput_ratio",
                 g_on["goodput"] / max(g_off["goodput"], 1),
                 f"interactive goodput {g_on['goodput']} alerted vs "
                 f"{g_off['goodput']} reactive (p95 {g_on['p95_ms']:.0f} "
                 f"vs {g_off['p95_ms']:.0f}ms)"))

    # the alerted run actually spun standby capacity up EARLY: its first
    # scale-up precedes the reactive run's scheduled one
    t_scale_on = min((t for t, d, _ in alerted.scale_events if d == "up"),
                     default=float("inf"))
    t_scale_off = min((t for t, d, _ in reactive.scale_events
                       if d == "up"), default=float("inf"))
    rows.append(("slo/alert_scaleup_lead_s",
                 max(0.0, t_scale_off - t_scale_on),
                 f"first spin-up t={t_scale_on:.1f}s alerted vs "
                 f"t={t_scale_off:.1f}s scheduled"))
    assert t_scale_on <= t_scale_off, (
        f"alerted run scaled at {t_scale_on}, after the reactive "
        f"scheduled instant {t_scale_off}")

    # determinism: the monitoring-only day is bit-identical on replay
    again, _ = run_day(horizon_s, actuate=False)
    assert again.summary() == reactive.summary(), (
        "watchtower day is not deterministic")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short horizon (fast CI path)")
    args = ap.parse_args()
    for r in run(smoke=args.smoke):
        print(",".join(str(c) for c in r))
