"""Traffic layer: SLO-aware admission+preemption vs FIFO/no-admission,
and the bucketed serving data path vs the pad-to-max baseline.

Three request classes share one chip pool through a contention trace
(co-running phase halves the pool, a thermal window caps the ladder):

* ``interactive`` — bursty ON-OFF stream, tight deadline, high priority,
  SHED drop policy (the class preemption + shedding exist for);
* ``vision``      — steady Poisson stream, mid deadline/priority;
* ``greedy-rt``   — a Poisson stream whose deadline NO operating point
  can meet: SLO admission rejects it at registration; the FIFO baseline
  admits it and lets its best-effort slice clog the pool.

Both policies replay the SAME seeded arrival trace through the same
arbiter code; the SLO policy must deliver strictly more goodput at
equal-or-lower interactive p95 (asserted).

A second comparison replays one seeded trace under the two SERVICE
models: ``bucketed`` (a batch of k requests pays the nearest power-of-two
bucket latency — the engine's new data path) vs ``padded`` (every batch
pays the full pad-to-max forward — the old data path).  At low occupancy
(mean batch <= max_batch/2) bucketed must deliver >= 1.25x the goodput
with no interactive p95 regression (asserted — the PR's headline number).

    PYTHONPATH=src python benchmarks/bench_traffic.py [--smoke]
"""
from __future__ import annotations

from repro.core.types import ElasticSpace
from repro.runtime import GlobalConstraints, default_hw_states, model_lut
from repro.runtime import hwmodel as hm
from repro.traffic import (BUCKETED_SERVICE, DEGRADE, FIFO_POLICY,
                           PADDED_SERVICE, REJECT, SHED, SLO_POLICY,
                           SLOClass, onoff, poisson, simulate)

TOTAL_CHIPS = 256
POWER_BUDGET_W = 0.9 * TOTAL_CHIPS * hm.TDP_W
INTERVAL_S = 0.1

SPACE = ElasticSpace(width_mults=(0.5, 0.75, 1.0), ffn_mults=(0.5, 1.0),
                     depth_mults=(0.5, 1.0))
_REF_TERMS = hm.RooflineTerms(t_compute=0.02, t_memory=0.008,
                              t_collective=0.004)

# (class, roofline scale vs the reference cell)
CLASSES = (
    (SLOClass("interactive", deadline_ms=60.0, priority=2,
              drop_policy=SHED), 1.0),
    (SLOClass("vision", deadline_ms=150.0, priority=1,
              drop_policy=SHED), 0.4),
    (SLOClass("greedy-rt", deadline_ms=4.0, priority=0,
              drop_policy=REJECT), 1.6),
)


def make_luts():
    hw_states = default_hw_states(TOTAL_CHIPS)
    luts = {}
    for cls, scale in CLASSES:
        terms = hm.RooflineTerms(_REF_TERMS.t_compute * scale,
                                 _REF_TERMS.t_memory * scale,
                                 _REF_TERMS.t_collective * scale)
        luts[cls.name] = model_lut(SPACE.enumerate(), full_terms=terms,
                                   full_chips=TOTAL_CHIPS,
                                   hw_states=hw_states)
    return luts


def make_streams(horizon_s: float):
    """One seeded trace, replayed identically under both policies."""
    return {
        "interactive": onoff(40.0, horizon_s, on_s=1.0, off_s=1.0, seed=1),
        "vision": poisson(12.0, horizon_s, seed=2),
        "greedy-rt": poisson(15.0, horizon_s, seed=3),
    }


def g_fn(t: float) -> GlobalConstraints:
    """Shared machine conditions: a co-running phase halves the pool at
    1/3 of the horizon-agnostic 30 s cycle, a thermal window overlaps."""
    phase = t % 30.0
    chips = TOTAL_CHIPS // 2 if 10.0 <= phase < 16.0 else TOTAL_CHIPS
    throttle = 0.7 if 12.0 <= phase < 18.0 else 1.0
    return GlobalConstraints(total_chips=chips,
                             power_budget_w=POWER_BUDGET_W
                             * chips / TOTAL_CHIPS,
                             temperature_throttle=throttle)


# Bucketed-vs-padded comparison: a latency-sensitive class whose deadline
# (8ms, 6.4ms service budget) leaves little headroom over even the
# fastest operating point (~5.3ms full-batch forward).  Pad-to-max makes
# every small batch cost that full forward, so most queueing waits blow
# the deadline; bucketed serving pays ~overhead_frac of it and keeps the
# tail inside the budget.
_CMP_CLASSES = (
    (SLOClass("interactive", deadline_ms=8.0, priority=2,
              drop_policy=SHED, service_frac=0.8), 1.0),
    (SLOClass("batch", deadline_ms=400.0, priority=0,
              drop_policy=DEGRADE), 0.4),
)


def bucketed_vs_padded(horizon_s: float):
    """Replay one seeded low-occupancy trace under both service models."""
    hw_states = default_hw_states(TOTAL_CHIPS)
    luts = {}
    for cls, scale in _CMP_CLASSES:
        terms = hm.RooflineTerms(_REF_TERMS.t_compute * scale,
                                 _REF_TERMS.t_memory * scale,
                                 _REF_TERMS.t_collective * scale)
        luts[cls.name] = model_lut(SPACE.enumerate(), full_terms=terms,
                                   full_chips=TOTAL_CHIPS,
                                   hw_states=hw_states)
    classes = [cls for cls, _ in _CMP_CLASSES]
    streams = {"interactive": onoff(500.0, horizon_s, on_s=1.0, off_s=1.0,
                                    seed=11),
               "batch": poisson(3.0, horizon_s, seed=12)}
    g = lambda t: GlobalConstraints(total_chips=TOTAL_CHIPS,
                                    power_budget_w=POWER_BUDGET_W)
    reports = {}
    for model in (BUCKETED_SERVICE, PADDED_SERVICE):
        reports[model] = simulate(classes, luts, dict(streams), g,
                                  interval_s=INTERVAL_S, policy=SLO_POLICY,
                                  service_model=model)
    return classes, reports


def run(smoke: bool = False):
    horizon_s = 12.0 if smoke else 60.0
    luts = make_luts()
    classes = [cls for cls, _ in CLASSES]
    reports = {}
    for policy in (SLO_POLICY, FIFO_POLICY):
        reports[policy] = simulate(classes, luts, make_streams(horizon_s),
                                   g_fn, interval_s=INTERVAL_S,
                                   policy=policy)

    rows = []
    for policy, rep in reports.items():
        for name, cs in rep.classes.items():
            s = cs.summary()
            rows.append((f"traffic/{policy}/{name}/goodput", s["goodput"],
                         f"p95_ms={s['p95_ms']} dropped={s['dropped']} "
                         f"rejected={s['rejected']} "
                         f"completed={s['completed']}"))
        arb = rep.arbiter
        preempts = sum(a.get("preemptions", 0) for a in arb.values())
        rows.append((f"traffic/{policy}/goodput_total", rep.total_goodput,
                     f"dropped={rep.total_dropped} preemptions={preempts}"))

    slo, fifo = reports[SLO_POLICY], reports[FIFO_POLICY]
    p95_slo = slo.classes["interactive"].p(95)
    p95_fifo = fifo.classes["interactive"].p(95)
    rows.append(("traffic/interactive_p95_slo_vs_fifo_ms", p95_slo,
                 f"fifo={p95_fifo:.1f}ms"))
    assert slo.total_goodput > fifo.total_goodput, (
        f"SLO goodput {slo.total_goodput} <= FIFO {fifo.total_goodput}")
    assert p95_slo <= p95_fifo, (
        f"SLO interactive p95 {p95_slo:.1f}ms > FIFO {p95_fifo:.1f}ms")
    # admission control really fired: the infeasible class is rejected
    # under SLO and admitted (then always late) under FIFO
    assert slo.classes["greedy-rt"].rejected > 0
    assert fifo.classes["greedy-rt"].rejected == 0

    # --- bucketed serving vs the pad-to-max baseline (headline) -----------
    cmp_classes, cmp_reports = bucketed_vs_padded(horizon_s)
    bkt, pad = cmp_reports[BUCKETED_SERVICE], cmp_reports[PADDED_SERVICE]
    mean_batch = bkt.classes["interactive"].mean_batch
    max_batch = cmp_classes[0].max_batch
    for model, rep in cmp_reports.items():
        s = rep.classes["interactive"].summary()
        rows.append((f"traffic/serving_{model}/goodput", rep.total_goodput,
                     f"interactive p95_ms={s['p95_ms']} "
                     f"dropped={s['dropped']} mean_batch={s['mean_batch']}"))
    p95_bkt = bkt.classes["interactive"].p(95)
    p95_pad = pad.classes["interactive"].p(95)
    rows.append(("traffic/serving_bucketed_speedup",
                 bkt.total_goodput / max(pad.total_goodput, 1),
                 f"goodput {bkt.total_goodput} vs {pad.total_goodput}, "
                 f"p95 {p95_bkt:.1f} vs {p95_pad:.1f}ms, "
                 f"mean_batch={mean_batch:.2f}"))
    # low occupancy: the win comes from not padding, not from batching more
    assert mean_batch <= max_batch / 2, (
        f"comparison trace not low-occupancy: mean batch {mean_batch:.2f}")
    assert bkt.total_goodput >= 1.25 * pad.total_goodput, (
        f"bucketed goodput {bkt.total_goodput} < 1.25x padded "
        f"{pad.total_goodput}")
    assert p95_bkt <= p95_pad, (
        f"bucketed interactive p95 {p95_bkt:.1f}ms regressed vs padded "
        f"{p95_pad:.1f}ms")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short horizon (fast CI path)")
    args = ap.parse_args()
    for r in run(smoke=args.smoke):
        print(",".join(str(c) for c in r))
