"""Cluster layer: multi-node scale-out, routing policies, and admission.

Three experiments, all on seeded traces through the REAL per-node
arbiters via the virtual-time cluster simulator (deterministic —
rerunning reproduces every routing decision bit-for-bit):

* **scale-out** — one overloaded SHED class replayed against 1, 2 and 4
  identical 64-chip nodes.  One node saturates (~380 rps of bucketed
  capacity); two must deliver >= 1.7x its goodput on the SAME trace
  (asserted — near-linear scaling is the cluster's reason to exist);
* **skewed capacity** — a 256-chip node next to a 64-chip node (4:1)
  under a never-drop class.  Round-robin keeps feeding the slow node
  half the traffic and its queue (and the class p95) explodes;
  power-of-two-choices reads the backlog-per-chip signal and must hold
  p95 at-or-below round-robin's (asserted — the routing headline);
* **admission** — a latency class whose minimal share needs more chips
  than any small node has: `cluster_admission` must raise
  `AdmissionError` on a small-node-only cluster and admit the SAME class
  once a big node joins (asserted — scaling out turns rejects into
  placements).

    PYTHONPATH=src python benchmarks/bench_cluster.py [--smoke]
"""
from __future__ import annotations

from repro.cluster import (P2C, ROUND_ROBIN, ClusterNode, cluster_admission,
                           simulate_cluster)
from repro.core.types import ElasticSpace
from repro.runtime import AdmissionError, GlobalConstraints, model_lut
from repro.runtime import hwmodel as hm
from repro.traffic import DEGRADE, SHED, SLOClass, poisson

FULL_CHIPS = 256
INTERVAL_S = 0.1

SPACE = ElasticSpace(width_mults=(0.5, 0.75, 1.0), ffn_mults=(0.5, 1.0),
                     depth_mults=(0.5, 1.0))
_REF_TERMS = hm.RooflineTerms(t_compute=0.02, t_memory=0.008,
                              t_collective=0.004)


def make_lut(scale: float = 1.0):
    terms = hm.RooflineTerms(_REF_TERMS.t_compute * scale,
                             _REF_TERMS.t_memory * scale,
                             _REF_TERMS.t_collective * scale)
    return model_lut(SPACE.enumerate(), full_terms=terms,
                     full_chips=FULL_CHIPS)


def make_nodes(capacities):
    """Homogeneous-or-not node fleet: one g_fn per chip capacity."""
    return [ClusterNode(name=f"n{i}",
                        g_fn=lambda t, c=cap: GlobalConstraints(total_chips=c))
            for i, cap in enumerate(capacities)]


def scale_out(horizon_s: float):
    """One seeded overloaded trace vs 1/2/4-node clusters (p2c)."""
    cls = [SLOClass("api", deadline_ms=200.0, priority=2, drop_policy=SHED)]
    luts = {"api": make_lut()}
    stream = poisson(1000.0, horizon_s, seed=1)
    out = {}
    for n in (1, 2, 4):
        rep = simulate_cluster(cls, luts, {"api": list(stream)},
                               make_nodes([64] * n), router=P2C,
                               interval_s=INTERVAL_S)
        out[n] = rep
    return out


def skewed_routing(horizon_s: float):
    """4:1 skewed capacity (256 + 64 chips), p2c vs round-robin on the
    same trace.  DEGRADE (never shed) so queueing shows up in p95."""
    cls = [SLOClass("web", deadline_ms=200.0, priority=2,
                    drop_policy=DEGRADE)]
    luts = {"web": make_lut()}
    stream = poisson(1000.0, horizon_s, seed=2)
    out = {}
    for router in (P2C, ROUND_ROBIN):
        rep = simulate_cluster(cls, luts, {"web": list(stream)},
                               make_nodes([256, 64]), router=router,
                               interval_s=INTERVAL_S)
        out[router] = rep
    return out


def admission_scaling():
    """A 10ms class fits only a 256-chip node's headroom: rejected by a
    small-node cluster, admitted once a big node joins."""
    lut = make_lut()
    target_ms = 10.0
    small = make_nodes([64, 64])
    try:
        cluster_admission(small, lut, target_ms, priority=2)
        raise AssertionError("10ms class admitted on 64-chip nodes")
    except AdmissionError:
        pass
    placed = cluster_admission(make_nodes([64, 64, 256]), lut, target_ms,
                               priority=2)
    assert placed == ["n2"], placed
    return placed


def run(smoke: bool = False):
    horizon_s = 8.0 if smoke else 24.0
    rows = []

    # --- scale-out ---------------------------------------------------------
    scaled = scale_out(horizon_s)
    for n, rep in scaled.items():
        s = rep.classes["api"]
        rows.append((f"cluster/scale/{n}_node/goodput", s.good,
                     f"p95_ms={round(s.p(95), 1)} dropped={s.dropped} "
                     f"submitted={s.submitted}"))
    g1 = scaled[1].classes["api"].good
    g2 = scaled[2].classes["api"].good
    g4 = scaled[4].classes["api"].good
    rows.append(("cluster/scale/2_node_speedup", g2 / max(g1, 1),
                 f"goodput {g2} vs {g1} (4-node: {g4})"))
    assert g2 >= 1.7 * g1, (
        f"2-node goodput {g2} < 1.7x 1-node {g1} (acceptance)")
    assert g4 >= g2, f"4-node goodput {g4} regressed vs 2-node {g2}"

    # --- skewed-capacity routing ------------------------------------------
    skew = skewed_routing(horizon_s)
    p95 = {}
    for router, rep in skew.items():
        s = rep.classes["web"]
        p95[router] = s.p(95)
        rows.append((f"cluster/skew/{router}/p95_ms", s.p(95),
                     f"goodput={s.good} routed={rep.routed['web']}"))
    assert p95[P2C] <= p95[ROUND_ROBIN], (
        f"p2c p95 {p95[P2C]:.1f}ms > round-robin {p95[ROUND_ROBIN]:.1f}ms "
        f"under 4:1 skew (acceptance)")
    assert (skew[P2C].classes["web"].good
            >= skew[ROUND_ROBIN].classes["web"].good), "p2c goodput regressed"

    # --- admission across cluster sizes -----------------------------------
    placed = admission_scaling()
    rows.append(("cluster/admission/placements_after_scaleout", len(placed),
                 "AdmissionError on 2x64-chip nodes; admitted on +256"))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short horizon (fast CI path)")
    args = ap.parse_args()
    for r in run(smoke=args.smoke):
        print(",".join(str(c) for c in r))
