"""Placement engine: cluster-wide rebalancing vs static first-fit.

Three experiments on seeded virtual-time traces (deterministic — every
routing decision and migration reproduces bit-for-bit):

* **skew recovery** — an overloaded never-drop class first-fit-parked
  on ONE of three identical nodes.  The static run stays parked; the
  rebalanced run replays the SAME trace with periodic ``rebalance_at``
  instants, pays priced migrations (warmup + weight transfer, energy
  charged to the report) to scale the class out, and must deliver
  **>= 1.2x the static goodput at no higher energy per good request**
  (asserted — the placement headline; measured margin is far larger);
* **migration-storm guard** — steady balanced load through the same
  rebalance cadence must execute ZERO migrations (asserted — the
  hysteresis/no-flapping guarantee: a migration is only worth paying
  when the fresh global solve actually disagrees with where things
  are);
* **autoscale** — a burst against one UP + one STANDBY node: the
  backlog signal wakes the standby, which serves after its priced
  warmup (asserted — the ClusterNode lifecycle closes the loop).

    PYTHONPATH=src python benchmarks/bench_placement.py [--smoke]
"""
from __future__ import annotations

from repro.cluster import (FIRST_FIT, LEAST_LOADED, STANDBY, UP, ClusterNode,
                           simulate_cluster)
from repro.core.types import ElasticSpace
from repro.runtime import GlobalConstraints, model_lut
from repro.runtime import hwmodel as hm
from repro.traffic import DEGRADE, SHED, SLOClass, poisson

FULL_CHIPS = 256
GOODPUT_FLOOR = 1.2   # rebalanced/static acceptance ratio

SPACE = ElasticSpace(width_mults=(0.5, 0.75, 1.0), ffn_mults=(0.5, 1.0),
                     depth_mults=(0.5, 1.0))
_REF_TERMS = hm.RooflineTerms(t_compute=0.02, t_memory=0.008,
                              t_collective=0.004)


def make_lut():
    return model_lut(SPACE.enumerate(), full_terms=_REF_TERMS,
                     full_chips=FULL_CHIPS)


def make_nodes(capacities, states=None):
    nodes = [ClusterNode(name=f"n{i}",
                         g_fn=lambda t, c=cap: GlobalConstraints(
                             total_chips=c))
             for i, cap in enumerate(capacities)]
    for n, st in zip(nodes, states or []):
        n.state = st
    return nodes


def skew_recovery(horizon_s: float):
    """Static first-fit vs first-fit + periodic rebalance, same trace."""
    kw = dict(classes=[SLOClass("api", deadline_ms=200.0, priority=2,
                                drop_policy=DEGRADE)],
              luts={"api": make_lut()},
              streams={"api": poisson(2500.0, horizon_s, seed=5)},
              router=LEAST_LOADED, placement_mode=FIRST_FIT)
    static = simulate_cluster(nodes=make_nodes([256, 256, 256]), **kw)
    rebal = simulate_cluster(
        nodes=make_nodes([256, 256, 256]),
        rebalance_at=[0.5 + i for i in range(int(horizon_s))], **kw)
    return static, rebal


def steady_guard(horizon_s: float):
    """Balanced replicated load through the same rebalance cadence."""
    return simulate_cluster(
        [SLOClass("api", deadline_ms=200.0, priority=2, drop_policy=SHED)],
        {"api": make_lut()},
        {"api": poisson(300.0, horizon_s, seed=3)},
        make_nodes([256, 256]), router=LEAST_LOADED,
        rebalance_at=[0.5 + i for i in range(int(horizon_s))])


def autoscale(horizon_s: float):
    """A burst against UP + STANDBY: sustained backlog wakes the spare."""
    return simulate_cluster(
        [SLOClass("api", deadline_ms=200.0, priority=2,
                  drop_policy=DEGRADE)],
        {"api": make_lut()},
        {"api": poisson(3000.0, horizon_s, seed=13)},
        make_nodes([256, 256], states=[UP, STANDBY]),
        router=LEAST_LOADED,
        scale_at=[1.0 + i for i in range(int(horizon_s))])


def run(smoke: bool = False):
    horizon_s = 4.0 if smoke else 12.0
    rows = []

    # --- skew recovery: the headline ---------------------------------------
    static, rebal = skew_recovery(horizon_s)
    gs, gr = static.total_goodput, rebal.total_goodput
    mj_s = static.total_energy_mj / max(gs, 1)
    mj_r = rebal.total_energy_mj / max(gr, 1)
    ratio = gr / max(gs, 1)
    rows.append(("placement/rebalance_goodput_ratio", ratio,
                 f"goodput {gr} vs {gs} static, "
                 f"{len(rebal.migrations)} migrations"))
    rows.append(("placement/static/mj_per_good", mj_s,
                 f"goodput={gs} energy_mj={static.total_energy_mj:.0f}"))
    rows.append(("placement/rebalanced/mj_per_good", mj_r,
                 f"goodput={gr} energy_mj={rebal.total_energy_mj:.0f} "
                 f"(migration warmup {rebal.migration_energy_mj:.0f}mJ "
                 f"included)"))
    assert ratio >= GOODPUT_FLOOR, (
        f"rebalanced goodput {gr} < {GOODPUT_FLOOR}x static {gs} "
        f"(acceptance)")
    assert mj_r <= mj_s, (
        f"rebalanced energy/good {mj_r:.1f}mJ > static {mj_s:.1f}mJ "
        f"(acceptance: migrations must pay for themselves)")
    assert static.migrations == [], "static baseline must not migrate"

    # --- migration-storm guard ---------------------------------------------
    steady = steady_guard(horizon_s)
    rows.append(("placement/steady_migrations", len(steady.migrations),
                 f"{int(horizon_s)} rebalance instants, "
                 f"goodput={steady.total_goodput}"))
    assert steady.migrations == [], (
        f"steady load migrated {steady.migrations} (acceptance: "
        f"no flapping)")

    # --- autoscale ----------------------------------------------------------
    scaled = autoscale(horizon_s)
    ups = [e for e in scaled.scale_events if e[1] == "up"]
    rows.append(("placement/autoscale_spinups", len(ups),
                 f"goodput={scaled.total_goodput} "
                 f"events={scaled.scale_events}"))
    assert ups, "sustained backlog never woke the STANDBY node (acceptance)"
    assert any(d[2] == "n1" for d in scaled.decisions), (
        "woken node n1 never served traffic")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short horizon (fast CI path)")
    args = ap.parse_args()
    for r in run(smoke=args.smoke):
        print(",".join(str(c) for c in r))
