"""Calibration loop: closed-loop (measured) vs open-loop (analytic)
planning on the SAME seeded trace, with real servers.

The setup deliberately reproduces the open-loop failure mode the paper's
runtime layer exists to avoid: the analytic profile overestimates this
host's latency ~16x, so an uncalibrated arbiter can only trust points it
believes are fast enough — it parks every tenant on the full-frequency
ladder rung and burns modelled board power for no measured benefit.

Three phases, all on one seeded two-class trace (interactive + batch):

* **warm-up / baseline** — drive_live with an UNCALIBRATED arbiter while
  the servers record per-(subnet, bucket) dispatch→ready latency EWMAs
  and measured energy into a CalibrationStore.  This is also the
  uncalibrated live baseline (goodput + measured energy).
* **calibrated re-run** — same trace, fresh servers, arbiter given the
  warmed store: water-filling now plans off measured latency (every
  ladder rung meets the target, so the minimal share drops to the lowest
  DVFS point) and prices slices with measured watts.  Asserted: goodput
  >= the uncalibrated run's at <= its measured energy — the paper's
  energy objective, driven by observation.
* **replay parity** — the recorded trace replayed through simulate()
  twice: analytic vs calibration=store.  Asserted: the calibrated
  replay's interactive p95 error vs the LIVE p95 is strictly smaller
  than the analytic replay's.

    PYTHONPATH=src python benchmarks/bench_calibration.py [--smoke]
"""
from __future__ import annotations

import numpy as np

from repro.core.types import SubnetSpec
from repro.runtime import (CalibrationStore, GlobalConstraints,
                           ResourceArbiter, model_lut)
from repro.runtime import hwmodel as hm
from repro.traffic import DEGRADE, SLOClass, drive_live, poisson, simulate

FULL = SubnetSpec()
HALF = SubnetSpec(width_mult=0.5)
SPECS = [FULL, HALF]
INFLATE = 96.0        # analytic model's latency error vs this host
INTERVAL_S = 0.05


def tiny_stack():
    import jax
    from repro.models.vit import ViTConfig, vit_apply, vit_init
    from repro.runtime import DynamicServer
    cfg = ViTConfig(name="t", img_res=16, patch=8, n_layers=2, d_model=32,
                    n_heads=4, d_ff=64, n_classes=4, compute_dtype="float32")
    params = vit_init(jax.random.PRNGKey(0), cfg)
    dims = {"d_model": 32, "d_ff": 64, "n_heads": 4, "n_layers": 2}
    apply_fn = lambda p, x, E: vit_apply(p, x, cfg, E=E)[0]

    def mk_server(**kw):
        # max_batch=1: every request is exactly one dispatch, so measured
        # busy time is proportional to the request count and the
        # energy comparison between the two live runs isolates the POWER
        # of the chosen operating point (batch-formation timing would
        # otherwise add ~1.5x busy-time variance between runs)
        return DynamicServer(apply_fn, params, dims, timeout_ms=1.0,
                             max_batch=1, **kw)

    return mk_server


def drive_once(classes, lut, streams, mk_server, x, *, store,
               arbiter_store):
    """One live run: servers always RECORD into ``store``; the arbiter
    PLANS off ``arbiter_store`` (None = open-loop baseline)."""
    servers = {c.name: mk_server(calibration=store, tenant=c.name)
               for c in classes}
    for s in servers.values():
        s.warm(SPECS, example_input=x[0])
    arbiter = ResourceArbiter(interval_s=INTERVAL_S,
                              calibration=arbiter_store)
    for c in classes:
        arbiter.register(c.name, lut, target_latency_ms=c.service_target_ms,
                         priority=c.priority, server=servers[c.name])
    report = drive_live(classes, servers, arbiter,
                        {n: list(ts) for n, ts in streams.items()},
                        lambda name: x[0],
                        g_fn=lambda: GlobalConstraints(total_chips=2))
    energy = sum(row.get("measured_energy_mj", 0.0)
                 for row in report.arbiter.values() if isinstance(row, dict))
    return report, energy


def run(smoke: bool = False):
    horizon_s = 1.5 if smoke else 3.0
    mk_server = tiny_stack()
    x = np.zeros((8, 16, 16, 3), "float32")
    probe = mk_server()
    real_ms = probe.measure(FULL, x)     # true full-batch wall clock

    # analytic profile, INFLATE-times pessimistic about this host; target
    # sits just above the inflated full-spec latency so the open-loop
    # planner believes only the f=1.0 rung is fast enough
    terms = hm.RooflineTerms(INFLATE * real_ms / 1e3, 0.0, 0.0)
    hw_states = [hm.HwState(chips=1, freq=f) for f in hm.FREQ_LADDER]
    lut = model_lut(SPECS, full_terms=terms, full_chips=1,
                    hw_states=hw_states)
    target_ms = 1.06 * INFLATE * real_ms
    deadline_ms = max(50.0 * real_ms, 2 * target_ms)
    classes = [
        SLOClass("interactive", deadline_ms=deadline_ms, priority=2,
                 drop_policy=DEGRADE, service_frac=target_ms / deadline_ms,
                 max_batch=1),
        SLOClass("batch", deadline_ms=4 * deadline_ms, priority=0,
                 drop_policy=DEGRADE, max_batch=1,
                 service_frac=target_ms / (4 * deadline_ms)),
    ]
    streams = {"interactive": poisson(25.0, horizon_s, seed=7),
               "batch": poisson(10.0, horizon_s, seed=8)}

    # --- phase 1: uncalibrated baseline + calibration warm-up --------------
    store = CalibrationStore()
    base, energy_base = drive_once(classes, lut, streams, mk_server, x,
                                   store=store, arbiter_store=None)
    p95_live = base.classes["interactive"].p(95)
    assert store.latency_samples(FULL, 1) > 0, "warm-up recorded nothing"

    # --- phase 2: calibrated re-run (energy-aware water-filling) -----------
    cal, energy_cal = drive_once(classes, lut, streams, mk_server, x,
                                 store=store, arbiter_store=store)

    rows = [
        ("calibration/live/real_full_batch_ms", real_ms,
         f"analytic model claims {INFLATE:g}x this"),
        ("calibration/uncalibrated/goodput", base.total_goodput,
         f"measured_energy_mj={energy_base:.1f} "
         f"interactive_p95_ms={p95_live:.2f}"),
        ("calibration/calibrated/goodput", cal.total_goodput,
         f"measured_energy_mj={energy_cal:.1f} interactive_p95_ms="
         f"{cal.classes['interactive'].p(95):.2f}"),
        ("calibration/energy_ratio",
         energy_cal / max(energy_base, 1e-9),
         f"calibrated {energy_cal:.1f}mJ vs open-loop {energy_base:.1f}mJ "
         f"(lower is better)"),
    ]
    # acceptance: meets >= the open-loop targets at <= its measured energy
    assert cal.total_goodput >= base.total_goodput, (
        f"calibrated goodput {cal.total_goodput} < uncalibrated "
        f"{base.total_goodput}")
    assert energy_cal <= energy_base, (
        f"calibrated energy {energy_cal:.1f}mJ > uncalibrated "
        f"{energy_base:.1f}mJ")

    # --- phase 3: replay parity (simulate vs live) -------------------------
    g_fn = lambda t: GlobalConstraints(total_chips=2)
    analytic = simulate(classes, {c.name: lut for c in classes},
                        {n: list(ts) for n, ts in streams.items()},
                        g_fn, interval_s=INTERVAL_S)
    calibrated = simulate(classes, {c.name: lut for c in classes},
                          {n: list(ts) for n, ts in streams.items()},
                          g_fn, interval_s=INTERVAL_S, calibration=store)
    err_analytic = abs(analytic.classes["interactive"].p(95) - p95_live)
    err_cal = abs(calibrated.classes["interactive"].p(95) - p95_live)
    rows += [
        ("calibration/sim_analytic/p95_err_ms", err_analytic,
         f"predicted {analytic.classes['interactive'].p(95):.2f}ms vs "
         f"live {p95_live:.2f}ms"),
        ("calibration/sim_calibrated/p95_err_ms", err_cal,
         f"predicted {calibrated.classes['interactive'].p(95):.2f}ms vs "
         f"live {p95_live:.2f}ms"),
        ("calibration/p95_err_ratio", err_cal / max(err_analytic, 1e-9),
         "calibrated replay error / analytic replay error (lower better)"),
    ]
    assert err_cal < err_analytic, (
        f"calibrated p95 error {err_cal:.2f}ms not below analytic "
        f"{err_analytic:.2f}ms")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short trace (fast CI path)")
    args = ap.parse_args()
    for r in run(smoke=args.smoke):
        print(",".join(str(c) for c in r))
