"""Chaos day: request reliability on vs off, same seeded fault scenario.

One deterministic virtual-time "chaos day" against a 4-node cluster —
a correlated rack failure takes out half the fleet at t=1s, a thermal
DVFS ladder degrades one survivor, and recurring network partitions
blind the router to BOTH survivors for sub-second windows — replayed
twice on the same seeded arrival trace:

* **reliability off** — the seed behaviour: queued work on the dead
  rack resolves ``failed``, arrivals during the partition windows are
  dropped ("placements exist but none routable");
* **reliability on** — per-class deadline-aware retries with
  exponential backoff re-route that work through the router once the
  fault clears, hedged interactive requests ride out single-replica
  stalls, and sustained pressure flips classes into brownout (serve
  degraded instead of dropping).

The post-fault cluster has SLACK — retries fill chips the off-run
leaves idle while dropping work, which is exactly when a reliability
layer pays.  Acceptance (asserted here, compare-gated in run.py):

* reliability-on goodput >= 1.5x reliability-off on the same day;
* zero lost requests: submitted == rejected+dropped+failed+completed
  for every class in both runs;
* retries stay inside the cluster budget:
  granted <= burst + fraction x completed.

    PYTHONPATH=src python benchmarks/bench_chaos.py [--smoke]
"""
from __future__ import annotations

from repro.chaos import (PARTITION, RACK_FAIL, THERMAL, BrownoutPolicy,
                         Injection, Reliability, RetryBudget, RetryPolicy,
                         Scenario)
from repro.cluster import P2C, ClusterNode, simulate_cluster
from repro.core.types import ElasticSpace
from repro.runtime import GlobalConstraints, model_lut
from repro.runtime import hwmodel as hm
from repro.traffic import DEGRADE, SHED, SLOClass, poisson

GOODPUT_FLOOR = 1.5   # reliability-on / reliability-off acceptance ratio
FULL_CHIPS = 256

SPACE = ElasticSpace(width_mults=(0.5, 0.75, 1.0), ffn_mults=(0.5, 1.0),
                     depth_mults=(0.5, 1.0))
_REF_TERMS = hm.RooflineTerms(t_compute=0.02, t_memory=0.008,
                              t_collective=0.004)


def make_lut():
    return model_lut(SPACE.enumerate(), full_terms=_REF_TERMS,
                     full_chips=FULL_CHIPS)


def make_nodes():
    return [ClusterNode(name=f"n{i}",
                        g_fn=lambda t: GlobalConstraints(total_chips=64))
            for i in range(4)]


def chaos_day(horizon_s: float) -> Scenario:
    """Rack failure + thermal throttling + recurring partitions."""
    inj = [Injection(t=1.0, kind=RACK_FAIL, nodes=("n0", "n1")),
           Injection(t=1.1, kind=THERMAL, node="n2", duration_s=1.0)]
    t = 1.4
    while t + 0.9 <= horizon_s - 0.2:
        # both survivors partitioned: no reachable replica for the
        # window — the off-run drops these arrivals, the on-run's
        # backoffs outlive the window and re-route them
        inj.append(Injection(t=t, kind=PARTITION, node="n2",
                             duration_s=0.9))
        inj.append(Injection(t=t, kind=PARTITION, node="n3",
                             duration_s=0.9))
        t += 1.3
    return Scenario(name="chaos-day", seed=0, injections=tuple(inj))


def make_classes():
    # interactive degrades to 450ms (< its 600ms deadline), so brownout
    # completions still count good; batch never drops and has the
    # deadline slack to absorb a full backoff ladder
    return [SLOClass("interactive", deadline_ms=600.0, priority=3,
                     drop_policy=SHED, degrade_factor=1.5),
            SLOClass("batch", deadline_ms=2500.0, priority=1,
                     drop_policy=DEGRADE)]


def make_reliability() -> Reliability:
    # backoff ladders are sized to OUTLIVE a 0.9s partition window
    # (0.1+0.2+0.4 / 0.15+0.3+0.6), deadline-awareness prunes the rest
    return Reliability(
        policies={"interactive": RetryPolicy(max_attempts=5, backoff_s=0.1,
                                             backoff_mult=2.0, hedge=True)},
        default=RetryPolicy(max_attempts=5, backoff_s=0.15,
                            backoff_mult=2.0),
        budget=RetryBudget(fraction=2.0, burst=512),
        brownout=BrownoutPolicy())


def run_day(horizon_s: float, reliability):
    return simulate_cluster(
        make_classes(), {"interactive": make_lut(), "batch": make_lut()},
        {"interactive": poisson(100.0, horizon_s, seed=7),
         "batch": poisson(400.0, horizon_s, seed=8)},
        make_nodes(), router=P2C, chaos=chaos_day(horizon_s),
        reliability=reliability)


def lost_futures(report) -> int:
    """Requests that vanished from the accounting — must be zero."""
    return sum(abs(s.submitted - (s.rejected + s.dropped + s.failed
                                  + s.completed))
               for s in report.classes.values())


def run(smoke: bool = False):
    horizon_s = 7.0 if smoke else 10.0
    rows = []

    rel = make_reliability()
    off = run_day(horizon_s, None)
    on = run_day(horizon_s, rel)
    g_off, g_on = off.total_goodput, on.total_goodput
    ratio = g_on / max(g_off, 1)
    retried = sum(s.retried for s in on.classes.values())
    hedge_wasted = sum(s.hedge_wasted for s in on.classes.values())
    rows.append(("chaos/reliability_goodput_ratio", ratio,
                 f"goodput {g_on} vs {g_off} off, {retried} retries "
                 f"({on.retry_granted} granted), {len(on.injections)} "
                 f"injections"))
    rows.append(("chaos/off/goodput", g_off,
                 f"failed={off.total_failed} dropped={off.total_dropped}"))
    rows.append(("chaos/on/goodput", g_on,
                 f"failed={on.total_failed} dropped={on.total_dropped} "
                 f"hedge_wasted={hedge_wasted} "
                 f"brownout_transitions={len(on.brownouts)} "
                 f"retry_denied={on.retry_denied}"))
    assert ratio >= GOODPUT_FLOOR, (
        f"reliability-on goodput {g_on} < {GOODPUT_FLOOR}x off {g_off} "
        f"(acceptance)")

    # zero lost requests: every arrival terminally accounted, both runs
    lost = lost_futures(off) + lost_futures(on)
    rows.append(("chaos/lost_futures", float(lost),
                 "submitted == rejected+dropped+failed+completed, "
                 "per class, both runs"))
    assert lost == 0, f"{lost} requests vanished from the accounting"

    # retries never exceed the cluster budget allowance
    completed = sum(s.completed for s in on.classes.values())
    allowance = rel.budget.burst + rel.budget.fraction * completed
    frac = on.retry_granted / max(allowance, 1.0)
    rows.append(("chaos/retry_budget_frac", frac,
                 f"granted={on.retry_granted} <= allowance "
                 f"{allowance:.0f} (burst {rel.budget.burst} + "
                 f"{rel.budget.fraction} x {completed} completed)"))
    assert on.retry_granted <= allowance, (
        f"retries {on.retry_granted} exceeded budget {allowance:.0f} "
        f"(acceptance)")

    # the brownout machinery actually engaged and disengaged on the day
    directions = [d for _, _, d in on.brownouts]
    assert "enter" in directions and "exit" in directions, on.brownouts
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short horizon (fast CI path)")
    args = ap.parse_args()
    for r in run(smoke=args.smoke):
        print(",".join(str(c) for c in r))
