"""Guarded-by instrumentation cost: guards OFF must be free.

Replays bench_traffic's seeded contention trace through
:func:`repro.traffic.simulate` three ways — uninstrumented (guards
disabled, the production default), then with ``guarded_by`` assertions
enabled on the arbiter/engine/cluster hot state — and gates on:

* ``analysis/guard_overhead_ratio`` — guards-off goodput / uninstrumented
  goodput.  With guards disabled the declarations are registry entries
  only (no descriptors installed — asserted structurally), so the data
  path is literally the same code; the ratio must be >= 0.97 (headline,
  gated as an absolute floor by ``run.py --compare``) and the reports
  must be IDENTICAL (asserted);
* guards ON must not change the *measured* virtual-time report either
  (asserted identical): lock-ownership assertions observe the schedule,
  they must never perturb it;
* wall-clock cost of the enabled descriptors is reported
  (informational — host-dependent, not gated).

    PYTHONPATH=src python benchmarks/bench_analysis.py [--smoke]
"""
from __future__ import annotations

import time

from benchmarks.bench_traffic import CLASSES, INTERVAL_S, g_fn, make_luts, \
    make_streams
from repro.analysis import guards
from repro.traffic import SLO_POLICY, simulate

GOODPUT_FLOOR = 0.97


def _one_run(horizon_s: float):
    luts = make_luts()
    classes = [cls for cls, _ in CLASSES]
    t0 = time.perf_counter()
    report = simulate(classes, luts, make_streams(horizon_s), g_fn,
                      interval_s=INTERVAL_S, policy=SLO_POLICY)
    return report, time.perf_counter() - t0


def run(smoke: bool = False):
    horizon_s = 12.0 if smoke else 60.0
    from repro.runtime.arbiter import ResourceArbiter

    guards.disable_guards()
    base, t_base = _one_run(horizon_s)

    guards.disable_guards()
    # structural half of the zero-overhead claim: no descriptor installed
    assert "_workloads" not in ResourceArbiter.__dict__, \
        "guards-off left a descriptor on ResourceArbiter"
    off, t_off = _one_run(horizon_s)

    guards.enable_guards()
    try:
        assert "_workloads" in ResourceArbiter.__dict__, \
            "enable_guards installed no descriptor"
        on, t_on = _one_run(horizon_s)
    finally:
        guards.disable_guards()

    ratio = off.total_goodput / max(base.total_goodput, 1)
    assert ratio >= GOODPUT_FLOOR, (
        f"guards-off goodput {off.total_goodput} < "
        f"{GOODPUT_FLOOR}x uninstrumented {base.total_goodput}")
    # virtual time makes the stronger claim checkable: identical reports
    assert off.summary() == base.summary(), \
        "guards-off run changed the measured report"
    assert on.summary() == base.summary(), \
        "guards-on run changed the measured report"

    wall_off = t_off / max(t_base, 1e-9)
    wall_on = t_on / max(t_base, 1e-9)
    return [
        ("analysis/guard_overhead_ratio", ratio,
         f"goodput {off.total_goodput} guards-off vs {base.total_goodput} "
         f"uninstrumented (floor {GOODPUT_FLOOR})"),
        ("analysis/guards_off_wall_ratio", wall_off,
         f"{t_off * 1e3:.1f}ms off vs {t_base * 1e3:.1f}ms uninstrumented "
         f"(informational, host-dependent)"),
        ("analysis/guards_on_wall_ratio", wall_on,
         f"{t_on * 1e3:.1f}ms on vs {t_base * 1e3:.1f}ms uninstrumented "
         f"(informational: the price REPRO_GUARDS=1 pays)"),
    ]


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short horizon (fast CI path)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, val, derived in run(smoke=args.smoke):
        print(f"{name},{val:.3f},{derived}")
