"""Benchmark harness — one module per paper result + the roofline table.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call is the natural
scalar of each row: wall-clock us, energy, %, or roofline time).

``--json PATH`` additionally writes the rows as machine-readable JSON
(``{"suites": {title: [{"name", "value", "derived"}]}, ...}``) so the
perf trajectory accumulates across PRs (BENCH_<n>.json files at the repo
root; BENCH_3.json records the bucketed-vs-padded serving comparison,
BENCH_4.json the cluster scale-out and p2c-vs-round-robin routing).
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback


def main() -> None:
    import benchmarks.bench_arbiter as ba
    import benchmarks.bench_cluster as bc
    import benchmarks.bench_governor as bg
    import benchmarks.bench_kernels as bk
    import benchmarks.bench_pareto as bp
    import benchmarks.bench_switching as bs
    import benchmarks.bench_traffic as bt
    import benchmarks.roofline_table as rt

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast path for suites that support it")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write per-benchmark metrics as JSON")
    args = ap.parse_args()

    suites = [
        ("pareto (paper: Dynamic-OFA vs static)", bp.run),
        ("governor (paper: energy vs Linux governors)", bg.run),
        ("arbiter (multi-workload vs independent governors)", ba.run),
        ("traffic (SLO admission+preemption vs FIFO; bucketed vs padded)",
         lambda: bt.run(smoke=args.smoke)),
        ("cluster (multi-node scale-out, p2c vs round-robin, admission)",
         lambda: bc.run(smoke=args.smoke)),
        ("switching (paper: runtime architecture switching)", bs.run),
        ("kernels (elastic matmul / flash attention)", bk.run),
        ("roofline (dry-run derived)", rt.rows),
    ]
    failures = 0
    results = {}
    print("name,us_per_call,derived")
    for title, fn in suites:
        print(f"# --- {title}")
        try:
            rows = list(fn())
            for name, val, derived in rows:
                print(f"{name},{val:.3f},{derived}")
            results[title] = [{"name": name, "value": val,
                               "derived": str(derived)}
                              for name, val, derived in rows]
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema": 1, "smoke": args.smoke,
                       "failures": failures, "suites": results},
                      f, indent=1, sort_keys=True)
        print(f"# wrote {args.json}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
