"""Benchmark harness — one module per paper result + the roofline table.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call is the natural
scalar of each row: wall-clock us, energy, %, or roofline time).

``--json PATH`` additionally writes the rows as machine-readable JSON
(``{"suites": {title: [{"name", "value", "derived"}]}, ...}``) so the
perf trajectory accumulates across PRs (BENCH_<n>.json files at the repo
root; BENCH_3.json records the bucketed-vs-padded serving comparison,
BENCH_4.json the cluster scale-out and p2c-vs-round-robin routing,
BENCH_5.json the calibration loop: closed-loop energy ratio and replay
p95-error ratio, BENCH_6.json the placement engine: rebalanced-vs-static
goodput under skew and the zero-migration steady-load guard,
BENCH_8.json the chaos day: reliability-on vs reliability-off goodput
under a rack failure + thermal + partition scenario, BENCH_9.json the
watchtower throttle day: alert-driven actuation vs reactive baseline
plus burn-rate attribution accuracy).

``--suite SUBSTR`` runs only the suites whose title contains SUBSTR —
the tier-1 smoke test uses it to gate the placement headline in seconds
instead of re-running every paper experiment.

``--compare PREV.json`` guards the trajectory: after the run, every
HEADLINE metric present in both the previous file and this run is
checked for a >10 % regression in its bad direction (goodput/speedups
falling, error/energy ratios rising) and the process exits non-zero if
any regressed — CI wires two invocations together as a perf gate.
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback

# Headline metrics --compare guards.  Deterministic (seeded virtual-time)
# metrics are gated RELATIVE to the previous file: a >tol move in the bad
# direction fails.  Live wall-clock ratios vary several-fold run to run
# (host contention), so prev-relative gating would false-flag honest
# runs — they are gated against an ABSOLUTE ceiling instead (the same
# invariant the bench itself asserts: calibrated must beat open-loop).
HEADLINES = {
    "traffic/serving_bucketed_speedup": {"direction": "higher",
                                         "tol": 0.10},
    "cluster/scale/2_node_speedup": {"direction": "higher", "tol": 0.10},
    "calibration/energy_ratio": {"max": 1.0},
    "calibration/p95_err_ratio": {"max": 1.0},
    "placement/rebalance_goodput_ratio": {"direction": "higher",
                                          "tol": 0.10},
    # absolute: steady load must NEVER migrate, in any mode
    "placement/steady_migrations": {"max": 0.0},
    # absolute floor: tracing-on goodput / tracing-off goodput
    "obs/trace_overhead_ratio": {"min": 0.97},
    # absolute floor: reliability-on goodput / reliability-off goodput
    # on the seeded chaos day (rack failure + thermal + partitions)
    "chaos/reliability_goodput_ratio": {"min": 1.5},
    # absolute: no request may ever vanish from the accounting, and
    # retries may never exceed the cluster budget allowance
    "chaos/lost_futures": {"max": 0.0},
    "chaos/retry_budget_frac": {"max": 1.0},
    # absolute floor: fired alerts whose attribution names the
    # injected root cause on the seeded throttle day
    "slo/attribution_accuracy": {"min": 0.8},
    # absolute floor: alert-driven actuation must not make the day
    # worse than the reactive baseline (time-in-SLO ratio)
    "slo/alerted_time_in_slo_ratio": {"min": 1.0},
    # absolute floor: guards-off goodput / uninstrumented goodput —
    # guarded_by declarations must be free when REPRO_GUARDS is unset
    "analysis/guard_overhead_ratio": {"min": 0.97},
}
REGRESSION_TOL = 0.10


def _flatten(suites: dict) -> dict:
    out = {}
    for rows in suites.values():
        for row in rows:
            out[row["name"]] = row["value"]
    return out


def compare_headlines(prev_suites: dict, new_suites: dict) -> list:
    """[(name, prev, new, why)] for every regressed headline metric."""
    prev = _flatten(prev_suites)
    new = _flatten(new_suites)
    regressions = []
    for name, spec in HEADLINES.items():
        if name not in new:
            continue
        n = new[name]
        if "max" in spec:
            if n > spec["max"]:
                regressions.append((name, prev.get(name), n,
                                    f"above absolute ceiling "
                                    f"{spec['max']:g}"))
            continue
        if "min" in spec:
            if n < spec["min"]:
                regressions.append((name, prev.get(name), n,
                                    f"below absolute floor "
                                    f"{spec['min']:g}"))
            continue
        if name not in prev:
            continue
        p = prev[name]
        tol = spec.get("tol", REGRESSION_TOL)
        direction = spec["direction"]
        if direction == "higher" and n < p * (1.0 - tol):
            regressions.append((name, p, n,
                                f"higher is better, tol {tol:.0%}"))
        elif direction == "lower" and n > p * (1.0 + tol):
            regressions.append((name, p, n,
                                f"lower is better, tol {tol:.0%}"))
    return regressions


def main() -> None:
    import benchmarks.bench_analysis as ban
    import benchmarks.bench_arbiter as ba
    import benchmarks.bench_calibration as bcal
    import benchmarks.bench_chaos as bch
    import benchmarks.bench_cluster as bc
    import benchmarks.bench_governor as bg
    import benchmarks.bench_kernels as bk
    import benchmarks.bench_obs as bo
    import benchmarks.bench_pareto as bp
    import benchmarks.bench_placement as bpl
    import benchmarks.bench_slo as bslo
    import benchmarks.bench_switching as bs
    import benchmarks.bench_traffic as bt
    import benchmarks.roofline_table as rt

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast path for suites that support it")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write per-benchmark metrics as JSON")
    ap.add_argument("--compare", metavar="PREV_JSON", default=None,
                    help="exit non-zero on >10%% regression of any "
                         "headline metric vs a previous --json file")
    ap.add_argument("--suite", metavar="SUBSTR", default=None,
                    help="run only suites whose title contains SUBSTR")
    args = ap.parse_args()

    suites = [
        ("pareto (paper: Dynamic-OFA vs static)", bp.run),
        ("governor (paper: energy vs Linux governors)", bg.run),
        ("arbiter (multi-workload vs independent governors)", ba.run),
        ("traffic (SLO admission+preemption vs FIFO; bucketed vs padded)",
         lambda: bt.run(smoke=args.smoke)),
        ("cluster (multi-node scale-out, p2c vs round-robin, admission)",
         lambda: bc.run(smoke=args.smoke)),
        ("placement (rebalance vs static first-fit; no-flapping; "
         "autoscale)",
         lambda: bpl.run(smoke=args.smoke)),
        ("calibration (closed-loop measured planning vs open-loop)",
         lambda: bcal.run(smoke=args.smoke)),
        ("obs (tracing on vs off: goodput unchanged, decomposition)",
         lambda: bo.run(smoke=args.smoke)),
        ("chaos (seeded fault day: reliability on vs off)",
         lambda: bch.run(smoke=args.smoke)),
        ("slo (watchtower throttle day: alert-driven vs reactive)",
         lambda: bslo.run(smoke=args.smoke)),
        ("analysis (guarded-by assertions: off must be free)",
         lambda: ban.run(smoke=args.smoke)),
        ("switching (paper: runtime architecture switching)", bs.run),
        ("kernels (elastic matmul / flash attention)", bk.run),
        ("roofline (dry-run derived)", rt.rows),
    ]
    if args.suite:
        suites = [(title, fn) for title, fn in suites
                  if args.suite in title]
        if not suites:
            sys.exit(f"--suite {args.suite!r} matched no suite")
    failures = 0
    results = {}
    print("name,us_per_call,derived")
    for title, fn in suites:
        print(f"# --- {title}")
        try:
            rows = list(fn())
            for name, val, derived in rows:
                print(f"{name},{val:.3f},{derived}")
            results[title] = [{"name": name, "value": val,
                               "derived": str(derived)}
                              for name, val, derived in rows]
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema": 1, "smoke": args.smoke,
                       "failures": failures, "suites": results},
                      f, indent=1, sort_keys=True)
        print(f"# wrote {args.json}")
    if args.compare:
        with open(args.compare) as f:
            prev = json.load(f)
        regressions = compare_headlines(prev.get("suites", {}), results)
        for name, p, n, why in regressions:
            prev_s = "n/a" if p is None else f"{p:.3f}"
            print(f"# REGRESSION {name}: {prev_s} -> {n:.3f} ({why})")
        if regressions:
            sys.exit(2)
        print(f"# compare vs {args.compare}: no headline regression")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
