"""Observability overhead: the tracing-on stack must not change what it
measures.

Replays bench_traffic's seeded contention trace twice through
:func:`repro.traffic.simulate` — once bare, once with a :class:`Tracer`
and a :class:`MetricsRegistry` attached — and gates on:

* ``obs/trace_overhead_ratio`` — traced goodput / untraced goodput.
  The simulator is virtual-time, so tracing CANNOT change the measured
  schedule; the ratio must be >= 0.97 (headline, gated as an absolute
  floor by ``run.py --compare``) and the full report summaries must be
  IDENTICAL (asserted — the stronger form of "observability does not
  perturb the experiment");
* the retained span trees must decompose: per-class p50/p95 split into
  queue/collect/stack/dispatch/device sums back to the measured latency
  (``decompose_latency`` asserts the 5 % tolerance internally);
* wall-clock cost of carrying the tracer + registry through the run is
  reported (informational — host-dependent, not gated).

    PYTHONPATH=src python benchmarks/bench_obs.py [--smoke]
"""
from __future__ import annotations

import time

from benchmarks.bench_traffic import CLASSES, INTERVAL_S, g_fn, make_luts, \
    make_streams
from repro.obs import (MetricsRegistry, Tracer, decompose_latency,
                       to_chrome_trace, validate_schema)
from repro.traffic import SLO_POLICY, simulate

GOODPUT_FLOOR = 0.97


def run(smoke: bool = False):
    horizon_s = 12.0 if smoke else 60.0
    luts = make_luts()
    classes = [cls for cls, _ in CLASSES]

    t0 = time.perf_counter()
    bare = simulate(classes, luts, make_streams(horizon_s), g_fn,
                    interval_s=INTERVAL_S, policy=SLO_POLICY)
    t_bare = time.perf_counter() - t0

    tracer = Tracer(clock=lambda: 0.0)   # virtual time: spans are explicit
    metrics = MetricsRegistry()
    t0 = time.perf_counter()
    traced = simulate(classes, luts, make_streams(horizon_s), g_fn,
                      interval_s=INTERVAL_S, policy=SLO_POLICY,
                      tracer=tracer, metrics=metrics)
    t_traced = time.perf_counter() - t0

    ratio = traced.total_goodput / max(bare.total_goodput, 1)
    assert ratio >= GOODPUT_FLOOR, (
        f"tracing-on goodput {traced.total_goodput} < "
        f"{GOODPUT_FLOOR}x tracing-off {bare.total_goodput}")
    # virtual time makes the stronger claim checkable: byte-identical runs
    assert traced.summary() == bare.summary(), (
        "tracing changed the measured report")

    problems = validate_schema(tracer.spans())
    assert not problems, f"schema violations: {problems[:3]}"
    decomp = decompose_latency(tracer)   # asserts sums-to-total per trace
    events = len(to_chrome_trace(tracer)["traceEvents"])
    retained = len(tracer.requests())

    wall_ratio = t_traced / max(t_bare, 1e-9)
    rows = [
        ("obs/trace_overhead_ratio", ratio,
         f"goodput {traced.total_goodput} traced vs {bare.total_goodput} "
         f"untraced (floor {GOODPUT_FLOOR})"),
        ("obs/retained_traces", retained,
         f"dropped={tracer.dropped} decisions={len(tracer.decisions)} "
         f"perfetto_events={events}"),
        ("obs/wallclock_overhead_ratio", wall_ratio,
         f"{t_traced * 1e3:.1f}ms traced vs {t_bare * 1e3:.1f}ms bare "
         f"(informational, host-dependent)"),
    ]
    for cname, d in sorted(decomp.items()):
        p95 = d["p95"]
        parts = ", ".join(f"{k[:-3]}={v:.1f}" for k, v in sorted(p95.items())
                          if k.endswith("_ms") and k != "total_ms" and v > 0)
        rows.append((f"obs/decomp/{cname}/p95_ms", p95["total_ms"],
                     parts or "all-zero"))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short horizon (fast CI path)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, val, derived in run(smoke=args.smoke):
        print(f"{name},{val:.3f},{derived}")
