"""Paper result 1: Dynamic-OFA latency-accuracy Pareto vs static baselines.

Measures REAL wall-clock latency of sliced sub-networks of the paper's
supernet on this host (the mobile-CPU stand-in), pairs it with the
accuracy surrogate (modelled; examples/train_supernet.py measures real
accuracy on the synthetic task), and reports the Pareto curve that the
runtime governor deploys.  The paper's headline "up to 2.4-3.5x faster at
similar accuracy" corresponds to the latency span of the curve.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.pareto import OpPoint, accuracy_latency_front
from repro.core.types import SubnetSpec
from repro.runtime import DynamicServer, accuracy_surrogate
from repro.runtime.lut import subnet_flops_ratio


def run(batch: int = 8, n_subnets: int = 18):
    arch = get_arch("dynamic-ofa-supernet")
    cfg = arch.make_smoke()
    from repro.models.vit import vit_apply, vit_init
    params = vit_init(jax.random.PRNGKey(0), cfg)
    dims = {"d_model": cfg.d_model, "d_ff": cfg.d_ff,
            "n_heads": cfg.n_heads, "n_layers": cfg.n_layers}
    server = DynamicServer(lambda p, x, E: vit_apply(p, x, cfg, E=E)[0],
                           params, dims, max_batch=batch)
    x = np.random.default_rng(0).normal(
        size=(batch, cfg.img_res, cfg.img_res, 3)).astype(np.float32)

    specs = list(dict.fromkeys([cfg.elastic.max_spec(), cfg.elastic.min_spec()]
                               + list(cfg.elastic.enumerate(limit=n_subnets))))
    points = []
    for spec in specs:
        lat = server.measure(spec, x)
        acc = accuracy_surrogate(subnet_flops_ratio(spec))
        points.append(OpPoint(spec, None, lat, 0.0, acc))
    front = accuracy_latency_front(points)
    full = next(p for p in points if p.subnet == SubnetSpec())
    fastest = min(points, key=lambda p: p.latency_ms)
    rows = []
    for p in front:
        rows.append((f"pareto/{p.subnet.name()}", p.latency_ms * 1e3,
                     f"acc={p.accuracy:.2f}"))
    speedup = full.latency_ms / fastest.latency_ms
    rows.append(("pareto/speedup_full_vs_fastest", speedup,
                 f"paper claims up to 3.5x (CPU); measured {speedup:.2f}x"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(c) for c in r))
